"""Seeded-random property tests on the yCHG invariants (paper §1-2).

This is the pure-pytest fallback that runs on a bare install: the same
invariants as the hypothesis fuzz module (test_ychg_properties_hypothesis.py,
skipped via ``pytest.importorskip`` when hypothesis is absent), exercised
over a deterministic corpus of structured + seeded-random masks. See
tests/README.md for the optional-dependency policy.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ychg
from ychg_invariants import ALL_CHECKS, corpus, structured_masks

CORPUS = corpus()


@pytest.mark.parametrize("name", sorted(ALL_CHECKS))
def test_invariant_over_corpus(name):
    check = ALL_CHECKS[name]
    for img in CORPUS:
        check(img)


def test_corpus_is_diverse():
    """Guard the fallback's value: degenerate + random masks, both sparse and
    dense, multiple shapes — so a regression cannot hide behind a trivial
    corpus."""
    shapes = {img.shape for img in CORPUS}
    assert len(CORPUS) >= 30
    assert len(shapes) >= 10
    densities = [img.mean() for img in CORPUS]
    assert min(densities) == 0.0 and max(densities) == 1.0


def test_branch_merge_donut_counts():
    """The donut: one run splits into two (branch) then merges back. The
    count model sees 2 hyperedges (births at col 0 and col 1); the greedy
    materialised decomposition must split at both events -> 4 chains."""
    from repro.core import regions

    donut = structured_masks()[6]
    s = ychg.analyze(jnp.asarray(donut))
    np.testing.assert_array_equal(np.asarray(s.runs), [1, 2, 1])
    assert int(s.n_hyperedges) == 2
    assert len(regions.decompose(donut)) == 4


def test_same_count_reconnection_case():
    """Documented limitation of the poster's count signal: runs go 2 -> 2
    across a column where NO run overlaps its neighbour, so connectivity
    changes invisibly. The transition signal stays silent; the materialised
    decomposition must still break every chain."""
    from repro.core import regions

    reconnect = structured_masks()[7]
    s = ychg.analyze(jnp.asarray(reconnect))
    np.testing.assert_array_equal(np.asarray(s.runs), [2, 2])
    assert not bool(np.asarray(s.transitions)[1])   # signal misses the event
    assert int(s.n_hyperedges) == 2                 # count model: 2
    assert len(regions.decompose(reconnect)) == 4   # reality: 4 chains


def test_striped_generator_exact():
    """modis.striped hits its hyperedge-count target exactly."""
    from repro.data import modis

    for n in (0, 1, 7, 64, 147, 200):
        img = modis.striped(64, n)
        assert int(ychg.hyperedge_count(jnp.asarray(img))) == n


def test_conservation_batched():
    """check_conservation holds elementwise on a (B, H, W) stack."""
    rng = np.random.default_rng(42)
    imgs = (rng.random((6, 17, 23)) < 0.5).astype(np.uint8)
    s = ychg.analyze(jnp.asarray(imgs))
    assert np.asarray(ychg.check_conservation(s)).all()
