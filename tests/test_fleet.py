"""`repro.fleet` suite: ring placement, key stability, peering, routing.

Per the fleet policy in tests/README.md: loopback only, every port
ephemeral, no wall-clock assertions (gates and bounded polls pin the
interleavings), and the bit-identity bar applies through the router path
exactly as it does one layer down. "Workers" here are in-process
service + ServerThread pairs — subprocess workers (spawn, handshake,
restart) are exercised end to end by the fleet-smoke CI leg, not per-test.

The cross-process key-stability test is the exception that NEEDS a
subprocess: `serialize_key` exists precisely because tuple keys lean on
per-process `hash()`, so the test re-renders the same key under two
different ``PYTHONHASHSEED`` values and holds the bytes equal to the
parent's — the property consistent-hash placement (and every worker
restart) rides on.
"""

import json
import socket
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.engine import Engine, YCHGConfig
from repro.fleet import (
    FleetRouter,
    HashRing,
    PeeredResultCache,
    RouterConfig,
    RouterThread,
    WorkerLink,
)
from repro.fleet.router import routing_key
from repro.frontend import (
    FrontendOverloaded,
    ServerThread,
    YCHGClient,
    protocol,
)
from repro.service import ServiceConfig, YCHGService
from repro.service.cache import make_key, serialize_key

from test_service import _GatedEngine  # noqa: E402  (established pattern)

TIMEOUT = 300.0


def _mask(shape, seed=0, density=0.5):
    rng = np.random.default_rng(seed)
    return (rng.random(shape) < density).astype(np.uint8)


def _assert_host_equal(got, want):
    assert set(got) == set(want)
    for field in want:
        a, b = np.asarray(want[field]), np.asarray(got[field])
        assert a.shape == b.shape, field
        assert a.dtype == b.dtype, field
        assert np.array_equal(a, b), field


# ------------------------------------------------------------ hash ring


def test_ring_is_deterministic_and_balanced():
    nodes = ["w0", "w1", "w2", "w3"]
    ring_a, ring_b = HashRing(nodes), HashRing(nodes)
    keys = [serialize_key(make_key(_mask((16, 16), seed=s), "cpu", None))
            for s in range(200)]
    owners = [ring_a.node_for(k) for k in keys]
    # same nodes -> same ring -> same placement, in any process
    assert owners == [ring_b.node_for(k) for k in keys]
    counts = {n: owners.count(n) for n in nodes}
    # virtual nodes keep the split rough but never degenerate
    assert all(counts[n] > 0 for n in nodes), counts


def test_ring_removal_moves_only_the_dead_nodes_keys():
    nodes = ["w0", "w1", "w2", "w3"]
    ring = HashRing(nodes)
    keys = [serialize_key(make_key(_mask((16, 16), seed=s), "cpu", None))
            for s in range(200)]
    before = {k: ring.node_for(k) for k in keys}
    up = [n for n in nodes if n != "w1"]
    for k, owner in before.items():
        after = ring.node_for(k, up=up)
        if owner != "w1":
            assert after == owner   # survivors' keys never move
        else:
            assert after in up      # w1's keys land on live nodes only
    # failover is deterministic: the preference walk always names the
    # same successor for the same key
    for k in keys[:20]:
        assert ring.node_for(k, up=up) == [
            n for n in ring.preference(k) if n in up][0]


def test_ring_all_down_and_bad_construction():
    ring = HashRing(["w0", "w1"])
    key = b"anything"
    assert ring.node_for(key, up=[]) is None
    with pytest.raises(ValueError):
        HashRing([])
    with pytest.raises(ValueError):
        HashRing(["w0", "w0"])


# ------------------------------------------------------- key serialization


def test_serialize_key_distinguishes_every_component():
    mask = _mask((4, 8), seed=1)
    cfg = YCHGConfig()
    base = serialize_key(make_key(mask, "cpu", cfg))
    # same bytes, different shape: (4, 8) vs (8, 4)
    reshaped = np.ascontiguousarray(mask.reshape(8, 4))
    assert serialize_key(make_key(reshaped, "cpu", cfg)) != base
    # same bytes, different dtype view
    as_int8 = mask.view(np.int8)
    assert serialize_key(make_key(as_int8, "cpu", cfg)) != base
    # different backend / different config / different content
    assert serialize_key(make_key(mask, "ref", cfg)) != base
    cfg2 = YCHGConfig(block_w=cfg.block_w * 2)
    assert serialize_key(make_key(mask, "cpu", cfg2)) != base
    assert serialize_key(
        make_key(_mask((4, 8), seed=2), "cpu", cfg)) != base
    # different op on the same mask: per-op cache namespaces never alias
    assert serialize_key(make_key(mask, "cpu", cfg, op="ccl")) != base
    # and the rendering is pure: same inputs, same bytes
    assert serialize_key(make_key(mask, "cpu", YCHGConfig())) == base


def test_serialize_key_is_versioned_and_op_prefixed():
    """The v2 rendering leads with a version tag and a length-prefixed op
    component, so mixed-version fleet caches can never alias: a v1 key's
    first length-prefixed part was a 32-byte digest, a v2 key's is the
    11-byte version tag — differing first components, never equal bytes.
    The op part is length-prefixed, so ("ab", mask) and ("a", b-ish
    content) cannot collide by concatenation either."""
    mask = _mask((4, 8), seed=1)
    cfg = YCHGConfig()
    for op in ("ychg", "ccl", "denoise", "denoise+ychg"):
        skey = serialize_key(make_key(mask, "cpu", cfg, op=op))
        assert skey.startswith(
            len(b"ychg-key-v2").to_bytes(4, "big") + b"ychg-key-v2")
        # the op component follows, length-prefixed
        off = 4 + len(b"ychg-key-v2")
        n = int.from_bytes(skey[off:off + 4], "big")
        assert skey[off + 4:off + 4 + n] == op.encode()


_CHILD_SCRIPT = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.engine import YCHGConfig
    from repro.service.cache import make_key, serialize_key
    rng = np.random.default_rng(7)
    mask = (rng.random((32, 48)) < 0.5).astype(np.uint8)
    for op in ("ychg", "ccl", "denoise+ychg"):
        key = make_key(mask, "cpu", YCHGConfig(), op=op)
        sys.stdout.write(serialize_key(key).hex() + "\\n")
""")


def test_serialized_key_is_stable_across_processes():
    """The satellite bar: the serialized key must be byte-identical in
    processes with different hash seeds — tuple keys are not (hash()
    randomisation), which is exactly why routing serializes first. Since
    the v2 op component, every op's key (pipeline keys included) holds
    the same bar."""
    import os

    rng = np.random.default_rng(7)
    mask = (rng.random((32, 48)) < 0.5).astype(np.uint8)
    want = "".join(
        serialize_key(make_key(mask, "cpu", YCHGConfig(), op=op)).hex() + "\n"
        for op in ("ychg", "ccl", "denoise+ychg"))
    assert len(set(want.split())) == 3   # op-distinct, never aliased
    for seed in ("0", "1"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        out = subprocess.run(
            [sys.executable, "-c", _CHILD_SCRIPT], env=env,
            capture_output=True, text=True, timeout=TIMEOUT)
        assert out.returncode == 0, out.stderr
        assert out.stdout == want, (
            f"serialized key drifted under PYTHONHASHSEED={seed}")


# ------------------------------------------------------------- peering


def test_peer_probe_adopts_siblings_entry_without_recompute():
    """Worker B misses locally, finds the entry in sibling A's cache over
    the RPC probe, and serves it WITHOUT dispatching a batch — B's batch
    counter stays 0 and the result is bit-identical to A's."""
    mask = _mask((24, 24), seed=30)
    cfg = ServiceConfig(bucket_sides=(32,), max_batch=2, max_delay_ms=1.0)
    cache_a = PeeredResultCache(64)
    svc_a = YCHGService(Engine(), cfg, cache=cache_a)
    with svc_a, ServerThread(svc_a, rpc_port=0) as srv_a:
        want = svc_a.submit(mask).result(timeout=TIMEOUT).to_host()
        cache_b = PeeredResultCache(64)
        cache_b.set_peers([("127.0.0.1", srv_a.rpc_port)])
        svc_b = YCHGService(Engine(), cfg, cache=cache_b)
        with svc_b:
            got = svc_b.submit(mask).result(timeout=TIMEOUT).to_host()
            m = svc_b.metrics()
    _assert_host_equal(got, want)
    assert cache_b.peer_hits == 1
    assert m.peer_hits == 1
    assert m.batches == 0          # the whole point: no compute on B
    assert m.completed == 1
    # the adopted entry is now LOCAL: a repeat hits B's own cache
    assert cache_b.get(
        make_key(np.ascontiguousarray(mask),
                 svc_b.engine.resolve_backend(), svc_b.engine.config,
                 svc_b.engine.mesh)) is not None


def test_peer_probe_miss_and_dead_peer_fall_back_to_compute():
    """A sibling without the entry, then a dead peer: both are just
    misses — the service computes as if unpeered, and peering never
    makes a request fail."""
    mask = _mask((24, 24), seed=31)
    cfg = ServiceConfig(bucket_sides=(32,), max_batch=2, max_delay_ms=1.0)
    empty_cache = PeeredResultCache(64)
    svc_empty = YCHGService(Engine(), cfg, cache=empty_cache)
    with svc_empty, ServerThread(svc_empty, rpc_port=0) as srv_empty:
        # a dead port: bind-then-close guarantees nothing listens there
        s = socket.create_server(("127.0.0.1", 0))
        dead_port = s.getsockname()[1]
        s.close()
        cache = PeeredResultCache(64, probe_timeout_s=0.1)
        cache.set_peers([("127.0.0.1", dead_port),
                         ("127.0.0.1", srv_empty.rpc_port)])
        svc = YCHGService(Engine(), cfg, cache=cache)
        with svc:
            out = svc.submit(mask).result(timeout=TIMEOUT)
            m = svc.metrics()
    assert out.to_host()["runs"].shape == (24,)
    assert cache.peer_hits == 0
    assert cache.peer_misses == 1
    assert m.peer_misses == 1
    assert m.batches == 1          # computed locally


def test_cache_probe_rpc_verb_is_local_only():
    """The inbound probe answers from the local index and NEVER computes:
    probing a cold worker is a miss even though the worker could have
    computed the answer."""
    mask = _mask((16, 16), seed=32)
    cfg = ServiceConfig(bucket_sides=(16,), max_batch=1, max_delay_ms=1.0)
    cache = PeeredResultCache(64)
    svc = YCHGService(Engine(), cfg, cache=cache)
    with svc, ServerThread(svc, rpc_port=0) as srv:
        from repro.fleet.peering import probe_peer

        key = make_key(np.ascontiguousarray(mask),
                       svc.engine.resolve_backend(), svc.engine.config,
                       svc.engine.mesh)
        skey = serialize_key(key)
        assert probe_peer("127.0.0.1", srv.rpc_port, skey,
                          timeout=5.0) is None
        assert svc.metrics().batches == 0    # the probe computed nothing
        svc.submit(mask).result(timeout=TIMEOUT)
        frame = probe_peer("127.0.0.1", srv.rpc_port, skey, timeout=5.0)
        assert frame is not None and frame["hit"]
        # stored layout rides the wire: B=1 arrays, not the squeezed host view
        runs = protocol.decode_array(frame["result"]["runs"])
        assert runs.shape == (1, 16)


# ------------------------------------------------------------- the router


def _two_worker_fleet(cfg=None, engines=None):
    """Two in-process 'workers' (service + ServerThread with RPC) plus
    their links; caller closes via the returned closers list."""
    cfg = cfg or ServiceConfig(
        bucket_sides=(32,), max_batch=4, max_delay_ms=1.0)
    links, closers = [], []
    for i in range(2):
        engine = engines[i] if engines else Engine()
        cache = PeeredResultCache(64)
        svc = YCHGService(engine, cfg, cache=cache)
        srv = ServerThread(svc, rpc_port=0)
        links.append(WorkerLink(name=f"w{i}", host="127.0.0.1",
                                rpc_port=srv.rpc_port,
                                http_port=srv.port))
        closers.append((svc, srv))
    return links, closers


def _close_fleet(closers):
    for svc, srv in closers:
        srv.close()
        svc.close()


def test_router_path_is_bit_identical_and_uses_both_workers():
    masks = [_mask((28, 28), seed=40 + i) for i in range(8)]
    links, closers = _two_worker_fleet()
    try:
        cfg = ServiceConfig(bucket_sides=(32,), max_batch=4,
                            max_delay_ms=1.0)
        with YCHGService(Engine(), cfg) as ref:
            want = [ref.submit(m).result(timeout=TIMEOUT).to_host()
                    for m in masks]
        router = FleetRouter(links, RouterConfig(bucket_sides=(32,),
                                                 max_batch=4))
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            # single analyzes + a streamed batch, all through the router
            got0 = client.analyze(masks[0])
            _assert_host_equal(got0, want[0])
            items = {it.id: it for it in client.analyze_batch(masks)}
            for i, want_res in enumerate(want):
                assert items[i].ok, items[i].error
                _assert_host_equal(items[i].result, want_res)
            health = client.health()
            assert health["workers"] == {"w0": True, "w1": True}
        # placement actually spread over the ring for this mask set
        ring = HashRing(["w0", "w1"])
        owners = {ring.node_for(routing_key(m)) for m in masks}
        assert owners == {"w0", "w1"}, (
            "seed set no longer exercises both workers; pick new seeds")
    finally:
        _close_fleet(closers)


def test_router_reroutes_to_survivor_when_a_worker_dies():
    masks = [_mask((28, 28), seed=50 + i) for i in range(9)]
    links, closers = _two_worker_fleet()
    try:
        ring = HashRing(["w0", "w1"])
        # a mask owned by w1, so killing w1 forces a reroute
        victim_mask = next(m for m in masks
                           if ring.node_for(routing_key(m)) == "w1")
        cfg = ServiceConfig(bucket_sides=(32,), max_batch=4,
                            max_delay_ms=1.0)
        with YCHGService(Engine(), cfg) as ref:
            want = ref.submit(victim_mask).result(timeout=TIMEOUT).to_host()
        router = FleetRouter(links, RouterConfig(bucket_sides=(32,),
                                                 max_batch=4))
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            _assert_host_equal(client.analyze(victim_mask), want)
            svc1, srv1 = closers[1]
            srv1.close()           # w1's listeners vanish mid-fleet
            svc1.close()
            _assert_host_equal(client.analyze(victim_mask), want)
            metrics = client.metrics_text()
            assert "ychg_fleet_rerouted_total 1" in metrics
            assert 'ychg_fleet_worker_up{worker="w1"} 0' in metrics
            assert 'ychg_fleet_worker_up{worker="w0"} 1' in metrics
            health = client.health()
            assert health["workers"] == {"w0": True, "w1": False}
    finally:
        _close_fleet(closers)


def test_router_admission_sheds_429_when_workers_are_saturated():
    """Router-side DRR admission: one queue slot, held by a request
    parked in a gated worker engine — the second request sheds at the
    ROUTER with HTTP 429 before ever reaching a worker."""
    engines = [_GatedEngine(), _GatedEngine()]
    links, closers = _two_worker_fleet(engines=engines)
    holder_fut = {}
    try:
        router = FleetRouter(links, RouterConfig(
            bucket_sides=(32,), max_batch=4, max_queue_depth=1,
            overload_policy="shed"))
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            holder_mask, shed_mask = (_mask((28, 28), seed=60),
                                      _mask((28, 28), seed=61))
            t = threading.Thread(
                target=lambda: holder_fut.update(
                    out=client.analyze(holder_mask)),
                daemon=True)
            t.start()
            # the holder is admitted once it reaches a worker's engine
            deadline = time.monotonic() + TIMEOUT
            while not any(e.entered.is_set() for e in engines):
                assert time.monotonic() < deadline, "holder never arrived"
                time.sleep(0.005)
            with YCHGClient("127.0.0.1", rt.port) as shed_client:
                with pytest.raises(FrontendOverloaded) as exc_info:
                    shed_client.analyze(shed_mask)
            assert exc_info.value.status == 429
            assert exc_info.value.retry_after_s > 0
            for e in engines:
                e.resume.set()
            t.join(TIMEOUT)
            assert "runs" in holder_fut.get("out", {})
    finally:
        for e in engines:
            e.resume.set()
        _close_fleet(closers)


def test_router_429_retry_after_reflects_measured_drain_rate():
    """The 429 hint comes from the router's drain-rate estimator, not the
    old hardcoded 1.0 s: seed the estimator white-box with a known rate
    (10 completions/s) and the Retry-After must be (backlog + 1) / 10 =
    0.1 s — the parked holder is in flight at the worker, not queued, so
    backlog is 0 at shed time."""
    engines = [_GatedEngine(), _GatedEngine()]
    links, closers = _two_worker_fleet(engines=engines)
    holder_fut = {}
    try:
        router = FleetRouter(links, RouterConfig(
            bucket_sides=(32,), max_batch=4, max_queue_depth=1,
            overload_policy="shed"))
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            holder_mask, shed_mask = (_mask((28, 28), seed=62),
                                      _mask((28, 28), seed=63))
            t = threading.Thread(
                target=lambda: holder_fut.update(
                    out=client.analyze(holder_mask)),
                daemon=True)
            t.start()
            deadline = time.monotonic() + TIMEOUT
            while not any(e.entered.is_set() for e in engines):
                assert time.monotonic() < deadline, "holder never arrived"
                time.sleep(0.005)
            # seed: 10 completions over the last second; the huge interval
            # pins the samples against the loop's own observe() calls
            now = time.monotonic()
            router._drain._interval = 1e9
            router._drain._samples = [(now - 1.0, 0), (now, 10)]
            with YCHGClient("127.0.0.1", rt.port) as shed_client:
                with pytest.raises(FrontendOverloaded) as exc_info:
                    shed_client.analyze(shed_mask)
            assert exc_info.value.status == 429
            assert exc_info.value.retry_after_s == pytest.approx(
                0.1, abs=0.001)
            for e in engines:
                e.resume.set()
            t.join(TIMEOUT)
            assert "runs" in holder_fut.get("out", {})
    finally:
        for e in engines:
            e.resume.set()
        _close_fleet(closers)


def test_rollup_sums_worker_histograms_exactly():
    """Fixed bucket boundaries make the fleet rollup exact arithmetic:
    every ychg_request_latency_seconds series on the router's /metrics
    page equals the plain sum of the two workers' series, and the summed
    histogram stays internally consistent (_count == +Inf bucket)."""
    from repro.obs import base_family, parse_prom_text

    masks = [_mask((28, 28), seed=70 + i) for i in range(6)]
    links, closers = _two_worker_fleet()
    n_requests = len(masks) + 2
    try:
        router = FleetRouter(links, RouterConfig(bucket_sides=(32,),
                                                 max_batch=4))
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            items = {it.id: it for it in client.analyze_batch(masks)}
            assert all(it.ok for it in items.values())
            # a mixed-op recording: the rollup must stay exact arithmetic
            # when series carry distinct op label sets
            client.analyze(_mask((28, 28), seed=80), op="ccl")
            client.analyze(_mask((28, 28), seed=81), op="ccl")
            worker_pages = []
            for link in links:
                with YCHGClient("127.0.0.1", link.http_port) as wc:
                    worker_pages.append(parse_prom_text(wc.metrics_text()))
            page = parse_prom_text(client.metrics_text())
        fam = "ychg_request_latency_seconds"
        assert page.types.get(fam) == "histogram"

        def hist_series(p):
            return {(s.name, s.labels): s.value for s in p.samples
                    if base_family(s.name) == fam}

        want = {}
        for wp in worker_pages:
            for key, v in hist_series(wp).items():
                want[key] = want.get(key, 0.0) + v
        got = hist_series(page)
        assert want, "workers exported no latency histogram series"
        for key, v in want.items():
            assert got.get(key) == v, key
        inf = sum(v for (n, labels), v in got.items()
                  if n.endswith("_bucket") and dict(labels)["le"] == "+Inf")
        counts = sum(v for (n, _), v in got.items()
                     if n.endswith("_count"))
        assert inf == counts == n_requests
        # both ops' label sets survive the rollup distinctly
        ops_seen = {dict(labels).get("op") for (n, labels) in got
                    if n.endswith("_count")}
        assert {"ychg", "ccl"} <= ops_seen
        # the plain-counter legacy rollup behaviour still holds alongside
        assert page.get("ychg_completed_total") == n_requests
    finally:
        _close_fleet(closers)
