"""`repro.service` suite: the batching/caching/overlap layers above the engine.

Policy (tests/README.md §Service tests): no wall-clock assertions — the
threaded scheduler is verified through *parity* (every served result
bit-identical to ``engine.analyze`` on the raw mask, through padding,
bucketing, arrival order, duplicates, and caching), *counters* (registry
backend call counts prove cache hits skip compute; metrics prove the
compiled-shape bound), and *determinism knobs* (long ``max_delay_ms`` +
under-full buckets pin scheduling where a test needs it). Futures always
``result(timeout=...)`` with a generous bound so a scheduler bug fails,
never hangs, the suite.
"""

import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ychg
from repro.engine import Engine, YCHGConfig, registry
from repro.service import (
    ResultCache,
    ServiceConfig,
    ServiceOverloaded,
    YCHGService,
    make_key,
    pick_bucket_side,
    sub_batch_ladder,
)
from ychg_invariants import assert_bit_identical

TIMEOUT = 300.0  # generous future bound: fail, never hang


def _mask(shape, seed=0, density=0.5):
    rng = np.random.default_rng(seed)
    return (rng.random(shape) < density).astype(np.uint8)


def _assert_result_matches_analyze(result, mask):
    """Service result == engine.analyze(mask): dtypes, shapes, values."""
    assert_bit_identical(result.to_summary(), ychg.analyze(jnp.asarray(mask)))


# ------------------------------------------------------------------ parity


def test_service_parity_mixed_shapes_and_duplicates():
    """The tentpole bar: ragged shapes, interleaved arrival order, duplicate
    masks — every future resolves to exactly engine.analyze(mask)."""
    masks = [
        _mask((17, 23), seed=1),
        _mask((64, 64), seed=2),
        _mask((33, 40), seed=3),
        _mask((128, 100), seed=4),
        _mask((5, 128), seed=5),
        _mask((1, 1), seed=6),
        np.zeros((30, 30), np.uint8),          # blank: zero hyperedges
        np.ones((16, 48), np.uint8),           # full coverage
    ]
    masks += [masks[0].copy(), masks[3].copy()]  # duplicates, far apart
    with YCHGService(config=ServiceConfig(
            bucket_sides=(64, 128), max_batch=4, max_delay_ms=1.0)) as svc:
        futures = [svc.submit(m) for m in masks]
        for mask, fut in zip(masks, futures):
            res = fut.result(timeout=TIMEOUT)
            assert not res.batched and res.batch_size == 1
            _assert_result_matches_analyze(res, mask)


def test_service_parity_matches_plain_analyze_batch():
    """Satellite: the overlapped/bucketed path == one plain
    engine.analyze_batch over the same masks (same shape, so the comparison
    is a direct stack)."""
    masks = [_mask((48, 64), seed=s) for s in range(6)]
    engine = Engine()
    want = engine.analyze_batch(np.stack(masks))
    with YCHGService(engine, ServiceConfig(
            bucket_sides=(64,), max_batch=3, max_delay_ms=1.0)) as svc:
        outs = [f.result(timeout=TIMEOUT) for f in map(svc.submit, masks)]
    got = np.concatenate([np.asarray(o.runs) for o in outs])
    np.testing.assert_array_equal(got, np.asarray(want.runs))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(o.n_hyperedges) for o in outs]),
        np.asarray(want.n_hyperedges))


def test_service_parity_ragged_arrival_order():
    """Shuffled interleaving across buckets must not cross results over."""
    rng = np.random.default_rng(11)
    masks = [_mask(((s % 60) + 4, ((s * 7) % 90) + 4), seed=100 + s)
             for s in range(24)]
    order = rng.permutation(len(masks))
    with YCHGService(config=ServiceConfig(
            bucket_sides=(32, 64, 128), max_batch=4, max_delay_ms=1.0)) as svc:
        futures = {}
        for i in order:
            futures[i] = svc.submit(masks[i])
        for i, fut in futures.items():
            _assert_result_matches_analyze(fut.result(timeout=TIMEOUT), masks[i])


def test_service_nonbinary_and_nonuint8_masks():
    """int32 masks with values > 1 keep nonzero-is-foreground semantics
    through pad_stack (zero padding is inert for any dtype)."""
    mask = (np.arange(20 * 17).reshape(20, 17) % 5).astype(np.int32) * 7
    with YCHGService(config=ServiceConfig(
            bucket_sides=(32,), max_batch=2, max_delay_ms=1.0)) as svc:
        _assert_result_matches_analyze(svc.analyze(mask, timeout=TIMEOUT), mask)


# ------------------------------------------------------------------- cache


def test_cache_hit_skips_backend():
    """Satellite: a hit must not invoke the backend — asserted via the
    registry call counter the engine bumps on every dispatch."""
    mask = _mask((40, 40), seed=20)
    engine = Engine()
    backend = engine.resolve_backend()
    with YCHGService(engine, ServiceConfig(
            bucket_sides=(64,), max_batch=1, max_delay_ms=1.0)) as svc:
        first = svc.analyze(mask, timeout=TIMEOUT)
        n_after_miss = registry.call_count(backend)
        again = svc.analyze(mask.copy(), timeout=TIMEOUT)  # same bytes
        assert registry.call_count(backend) == n_after_miss
        assert again is first  # the cached object itself, no copy
        m = svc.metrics()
        assert m.cache_hits == 1 and m.cache_misses == 1


def test_cache_same_bytes_different_shape_or_dtype_misses():
    """Satellite: the key is content + shape + dtype — equal byte strings
    with different interpretation are different requests."""
    payload = (np.arange(32) % 2).astype(np.uint8)
    variants = [
        payload.reshape(4, 8),
        payload.reshape(8, 4),            # same bytes, different shape
        payload.reshape(4, 8).view(np.int8),  # same bytes, different dtype
    ]
    assert variants[0].tobytes() == variants[1].tobytes() == variants[2].tobytes()
    engine = Engine()
    backend = engine.resolve_backend()
    with YCHGService(engine, ServiceConfig(
            bucket_sides=(16,), max_batch=1, max_delay_ms=1.0)) as svc:
        before = registry.call_count(backend)
        for v in variants:
            _assert_result_matches_analyze(svc.analyze(v, timeout=TIMEOUT), v)
        assert registry.call_count(backend) == before + 3  # all misses
        assert svc.metrics().cache_hits == 0


def test_cache_different_engine_config_misses_in_shared_cache():
    """Keys embed (resolved backend, engine config): two services sharing
    one ResultCache never serve each other's entries."""
    mask = _mask((24, 24), seed=21)
    shared = ResultCache(64)
    cfg = ServiceConfig(bucket_sides=(32,), max_batch=1, max_delay_ms=1.0)
    with YCHGService(Engine(YCHGConfig(backend="jax")), cfg,
                     cache=shared) as a, \
         YCHGService(Engine(YCHGConfig(backend="fused")), cfg,
                     cache=shared) as b:
        ra = a.analyze(mask, timeout=TIMEOUT)
        n_fused = registry.call_count("fused")
        rb = b.analyze(mask, timeout=TIMEOUT)   # must MISS a's entry
        assert registry.call_count("fused") == n_fused + 1
        assert shared.misses == 2 and shared.hits == 0 and len(shared) == 2
        assert_bit_identical(ra.to_summary(), rb.to_summary())


def test_result_cache_lru_eviction_and_disable():
    cache = ResultCache(2)
    cfg = YCHGConfig()
    keys = [make_key(np.full((2, 2), i, np.uint8), "jax", cfg) for i in range(3)]
    cache.put(keys[0], "a"); cache.put(keys[1], "b")
    assert cache.get(keys[0]) == "a"      # refresh 0 -> 1 is now LRU
    cache.put(keys[2], "c")               # evicts 1
    assert cache.get(keys[1]) is None and cache.get(keys[2]) == "c"
    assert len(cache) == 2 and cache.hits == 2 and cache.misses == 1
    off = ResultCache(0)
    off.put(keys[0], "a")
    assert off.get(keys[0]) is None and len(off) == 0
    with pytest.raises(ValueError):
        ResultCache(-1)


def test_make_key_discriminates_every_component():
    from repro.sharding import make_batch_mesh

    a = _mask((4, 6), seed=1)
    base = make_key(a, "jax", YCHGConfig())
    assert make_key(a.copy(), "jax", YCHGConfig()) == base  # content-addressed
    assert make_key(a, "fused", YCHGConfig()) != base
    assert make_key(a, "jax", YCHGConfig(block_w=64)) != base
    assert make_key(1 - a, "jax", YCHGConfig()) != base     # different bytes
    # a meshed engine's results carry a different device layout: never
    # interchangeable with unmeshed entries through a shared cache
    assert make_key(a, "jax", YCHGConfig(), make_batch_mesh()) != base


# ------------------------------------------------- coalescing / scheduling


def test_duplicate_in_flight_coalesces_to_one_slot():
    """While a mask is queued, an identical submit joins the leader: one
    backend computation, both futures get the same result object."""
    mask = _mask((20, 20), seed=30)
    # max_batch=8 + long delay: both submits land in the same pending bucket
    with YCHGService(config=ServiceConfig(
            bucket_sides=(32,), max_batch=8, max_delay_ms=400.0)) as svc:
        f1 = svc.submit(mask)
        f2 = svc.submit(mask.copy())
        r1 = f1.result(timeout=TIMEOUT)
        r2 = f2.result(timeout=TIMEOUT)
        assert r1 is r2
        m = svc.metrics()
        assert m.coalesced == 1 and m.batches == 1 and m.completed == 2
        _assert_result_matches_analyze(r1, mask)


def test_compiled_shapes_bounded_by_bucket_and_sub_batch_ladders():
    """Acceptance bar: arbitrary traffic shapes never dispatch more distinct
    compiled shapes than bucket_sides x the power-of-two sub-batch ladder
    (one dtype) — sub-bucket padding must not unbound the shape budget."""
    rng = np.random.default_rng(31)
    sides = (32, 64, 128)
    max_batch = 4
    masks = [_mask((int(rng.integers(2, 128)), int(rng.integers(2, 128))),
                   seed=200 + s) for s in range(30)]
    with YCHGService(config=ServiceConfig(
            bucket_sides=sides, max_batch=max_batch, max_delay_ms=1.0)) as svc:
        for f in [svc.submit(m) for m in masks]:
            f.result(timeout=TIMEOUT)
        m = svc.metrics()
    ladder = sub_batch_ladder(max_batch)
    assert len(ladder) == int(np.log2(max_batch)) + 1
    assert m.n_compiled_shapes <= len(sides) * len(ladder)
    assert set(m.compiled_shapes) <= {
        (b, s, s) for s in sides for b in ladder}


def test_low_occupancy_flush_pads_to_sub_batch_not_max_batch():
    """A lone request must dispatch a (1, side, side) stack, not pay for
    max_batch - 1 blank images (the pad-to-max_batch regression)."""
    mask = _mask((40, 40), seed=90)
    with YCHGService(config=ServiceConfig(
            bucket_sides=(64,), max_batch=8, max_delay_ms=1.0)) as svc:
        _assert_result_matches_analyze(svc.analyze(mask, timeout=TIMEOUT),
                                       mask)
        m = svc.metrics()
    assert m.compiled_shapes == ((1, 64, 64),)
    # pad fraction is now only the side padding, not 8x image blanks
    assert m.pad_fraction == 1.0 - mask.size / (64 * 64)


def test_sub_batches_off_restores_pad_to_max_batch():
    """The sub_batches=False knob keeps the old policy available so
    benchmarks can compare both on one schedule."""
    mask = _mask((40, 40), seed=91)
    with YCHGService(config=ServiceConfig(
            bucket_sides=(64,), max_batch=8, max_delay_ms=1.0,
            sub_batches=False)) as svc:
        svc.analyze(mask, timeout=TIMEOUT)
        m = svc.metrics()
    assert m.compiled_shapes == ((8, 64, 64),)


def test_submit_validation_and_lifecycle():
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with pytest.raises(ValueError, match=r"\(H, W\)"):
        svc.submit(np.zeros((2, 3, 4), np.uint8))
    with pytest.raises(ValueError, match="largest service bucket"):
        svc.submit(np.zeros((17, 4), np.uint8))
    res = svc.analyze(np.zeros((8, 8), np.uint8), timeout=TIMEOUT)
    assert int(np.asarray(res.n_hyperedges)[0]) == 0
    svc.close()
    svc.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(np.zeros((8, 8), np.uint8))


def test_close_drains_queued_requests():
    """Requests still pending at close() are flushed, not dropped."""
    masks = [_mask((12, 12), seed=40 + i) for i in range(3)]
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=8, max_delay_ms=10_000.0))
    futures = [svc.submit(m) for m in masks]  # sit in the delay window
    svc.close()
    for mask, fut in zip(masks, futures):
        _assert_result_matches_analyze(fut.result(timeout=TIMEOUT), mask)


def test_cancelled_future_does_not_kill_scheduler():
    """A client cancelling its future must not crash the scheduler thread
    (set_result on a cancelled future raises InvalidStateError): the rest of
    the batch and all later requests must still resolve."""
    with YCHGService(config=ServiceConfig(
            bucket_sides=(16,), max_batch=8, max_delay_ms=200.0)) as svc:
        doomed = svc.submit(_mask((8, 8), seed=70))   # parked in the window
        survivor_mask = _mask((8, 8), seed=71)
        survivor = svc.submit(survivor_mask)
        assert doomed.cancel()                        # never marked running
        _assert_result_matches_analyze(
            survivor.result(timeout=TIMEOUT), survivor_mask)
        # scheduler is still alive: a fresh request completes too
        after = _mask((8, 8), seed=72)
        _assert_result_matches_analyze(svc.analyze(after, timeout=TIMEOUT),
                                       after)


def test_analyze_stream_bad_item_still_delivers_prior_results():
    """The one-item lookahead must not swallow a computed result when the
    NEXT item is invalid: the valid result is yielded first, then the
    ValueError surfaces on the following pull (the pre-lookahead contract)."""
    engine = Engine()
    good = _mask((6, 7), seed=73)
    gen = engine.analyze_stream([good, np.zeros((2, 2, 2, 2), np.uint8)])
    first = next(gen)
    _assert_result_matches_analyze(first, good)
    with pytest.raises(ValueError, match="stream items"):
        next(gen)


def test_service_config_validation():
    with pytest.raises(ValueError, match="ascending ladder"):
        ServiceConfig(bucket_sides=(128, 64))
    with pytest.raises(ValueError, match="ascending ladder"):
        ServiceConfig(bucket_sides=())
    with pytest.raises(ValueError, match="max_batch"):
        ServiceConfig(max_batch=0)
    with pytest.raises(ValueError, match="inflight_buckets"):
        ServiceConfig(inflight_buckets=0)
    with pytest.raises(ValueError, match="max_queue_depth"):
        ServiceConfig(max_queue_depth=0)
    with pytest.raises(ValueError, match="overload_policy"):
        ServiceConfig(overload_policy="drop")
    assert pick_bucket_side((5, 100), (64, 128)) == 128


def test_metrics_snapshot_consistency():
    masks = [_mask((40, 40), seed=50 + i) for i in range(5)]
    with YCHGService(config=ServiceConfig(
            bucket_sides=(64,), max_batch=2, max_delay_ms=1.0)) as svc:
        for f in [svc.submit(m) for m in masks + [masks[0]]]:
            f.result(timeout=TIMEOUT)
        m = svc.metrics()
    assert m.submitted == 6 and m.completed == 6
    assert m.cache_hits + m.cache_misses == 6
    assert m.queue_depth == 0
    assert 0.0 <= m.pad_fraction < 1.0
    assert m.p95_latency_ms >= m.p50_latency_ms >= 0.0
    assert m.backend in registry.backend_names()


# ------------------------------------- scheduler bugfix regressions (PR 4)


class _WindowCache(ResultCache):
    """Intercepts the first ``put`` so the test can run code inside the
    completion window (result ready, cache insert in progress)."""

    def __init__(self, capacity=64):
        super().__init__(capacity)
        self.entered = threading.Event()
        self.resume = threading.Event()
        self._intercepted = False

    def put(self, key, value):
        if not self._intercepted:
            self._intercepted = True
            self.entered.set()
            assert self.resume.wait(TIMEOUT), "window gate never released"
        super().put(key, value)


def test_duplicate_in_completion_window_never_redispatches():
    """Regression (coalescing/cache race): a duplicate submitted while the
    leader's completion is mid-flight must hit the cache or the leader —
    the pre-fix code popped the leader BEFORE the cache insert, so the
    duplicate saw neither and re-dispatched the whole computation."""
    mask = _mask((24, 24), seed=80)
    engine = Engine()
    backend = engine.resolve_backend()
    cache = _WindowCache()
    svc = YCHGService(engine, ServiceConfig(
        bucket_sides=(32,), max_batch=1, max_delay_ms=1.0), cache=cache)
    try:
        f1 = svc.submit(mask)
        # completion is now parked inside the cache insert: the result is
        # computed, the leader not yet retired — the pre-fix window
        assert cache.entered.wait(TIMEOUT)
        n_dispatched = registry.call_count(backend)
        box = {}
        t = threading.Thread(
            target=lambda: box.update(fut=svc.submit(mask.copy())),
            daemon=True)
        t.start()          # duplicate lands in the window
        cache.resume.set()
        t.join(TIMEOUT)
        r1 = f1.result(timeout=TIMEOUT)
        r2 = box["fut"].result(timeout=TIMEOUT)
        # the duplicate was served without moving the backend call counter
        assert registry.call_count(backend) == n_dispatched
        assert r2 is r1
        _assert_result_matches_analyze(r1, mask)
    finally:
        svc.close()


def test_cache_hits_do_not_skew_latency_percentiles():
    """Regression (metrics skew): repeat traffic served from the cache must
    not push ~0 ms samples into the latency window — pre-fix, nine hits
    dragged p50 to 0 and hid what a compute miss actually costs."""
    mask = _mask((32, 32), seed=81)
    with YCHGService(config=ServiceConfig(
            bucket_sides=(64,), max_batch=1, max_delay_ms=1.0)) as svc:
        svc.analyze(mask, timeout=TIMEOUT)              # one compute miss
        for _ in range(9):
            svc.analyze(mask.copy(), timeout=TIMEOUT)   # nine cache hits
        m = svc.metrics()
    assert m.completed == 10 and m.completed_from_cache == 9
    assert m.cache_hits == 9
    # the window holds exactly the one compute sample: both percentiles
    # equal it, and it is the real (nonzero) submit->ready latency
    assert m.p50_latency_ms == m.p95_latency_ms
    assert m.p50_latency_ms > 0.0


# --------------------------------------------- admission control (PR 4)


def test_overload_shed_raises_typed_error_and_counts():
    """At max_queue_depth under policy "shed", submit fails fast with
    ServiceOverloaded; admitted requests still resolve, and freed slots
    re-admit. The long delay window holds the admitted requests pending so
    the bound is deterministically occupied."""
    masks = [_mask((16, 16), seed=100 + i) for i in range(6)]
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=8, max_delay_ms=10_000.0,
        max_queue_depth=2, overload_policy="shed"))
    try:
        admitted = [svc.submit(m) for m in masks[:2]]
        for m_ in masks[2:]:
            with pytest.raises(ServiceOverloaded, match="max_queue_depth=2"):
                svc.submit(m_)
        met = svc.metrics()
        assert met.shed == 4 and met.blocked == 0
    finally:
        svc.close()   # drains the two admitted requests
    for mask, fut in zip(masks, admitted):
        _assert_result_matches_analyze(fut.result(timeout=TIMEOUT), mask)


def test_overload_admits_cache_hits_and_coalesces_for_free():
    """Cache hits and in-flight duplicates consume no queue slot: at a full
    queue they are still served, while a distinct mask sheds."""
    leader_mask = _mask((16, 16), seed=110)
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=8, max_delay_ms=10_000.0,
        max_queue_depth=1, overload_policy="shed"))
    try:
        f1 = svc.submit(leader_mask)              # occupies the only slot
        f2 = svc.submit(leader_mask.copy())       # coalesces: no slot needed
        with pytest.raises(ServiceOverloaded):
            svc.submit(_mask((16, 16), seed=111))  # distinct: shed
        m = svc.metrics()
        assert m.coalesced == 1 and m.shed == 1
    finally:
        svc.close()
    assert f2.result(timeout=TIMEOUT) is f1.result(timeout=TIMEOUT)
    _assert_result_matches_analyze(f1.result(timeout=TIMEOUT), leader_mask)


def test_per_bucket_bound_sheds_flood_not_minority():
    """Satellite (per-bucket fairness): under a skewed two-bucket load
    with ``bucket_queue_depth`` set, the flooded bucket sheds against its
    own allowance while the minority bucket's shed count stays ZERO and
    all its requests resolve. Determinism per the no-wall-clock policy:
    a long delay window holds admitted requests pending, so the flooded
    bucket's bound is occupied exactly when the excess submits arrive."""
    flood = [_mask((16, 16), seed=200 + i) for i in range(6)]
    minority = [_mask((32, 32), seed=300 + i) for i in range(2)]
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16, 32), max_batch=8, max_delay_ms=10_000.0,
        bucket_queue_depth=2, overload_policy="shed"))
    try:
        admitted = [svc.submit(m) for m in flood[:2]]   # fill the 16-bucket
        for m_ in flood[2:]:
            with pytest.raises(ServiceOverloaded,
                               match="bucket_queue_depth=2"):
                svc.submit(m_)
        # the minority bucket admits freely while the flood is shedding
        minority_futs = [svc.submit(m) for m in minority]
        met = svc.metrics()
        assert met.shed == 4 and met.blocked == 0
        assert met.shed_by_bucket == ((("ychg", 16, "uint8"), 4),)
    finally:
        svc.close()   # drains everything admitted
    for mask, fut in zip(flood[:2] + minority, admitted + minority_futs):
        _assert_result_matches_analyze(fut.result(timeout=TIMEOUT), mask)


class _GatedEngine(Engine):
    """Holds every dispatch at the analyze_batch door until released —
    pins "the queue is full because work is genuinely in flight"."""

    def __init__(self):
        super().__init__()
        self.entered = threading.Event()
        self.resume = threading.Event()

    def analyze_batch(self, stack):
        result = super().analyze_batch(stack)
        self.entered.set()
        assert self.resume.wait(TIMEOUT), "engine gate never released"
        return result


def test_overload_block_applies_backpressure_then_admits():
    """Policy "block": at the bound, submit waits (counted in blocked) and
    is admitted once a completion frees a slot — nothing is lost."""
    engine = _GatedEngine()
    m1, m2 = _mask((16, 16), seed=120), _mask((16, 16), seed=121)
    svc = YCHGService(engine, ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0,
        max_queue_depth=1, overload_policy="block"))
    try:
        f1 = svc.submit(m1)
        assert engine.entered.wait(TIMEOUT)   # m1 holds the only slot
        box = {}
        t = threading.Thread(target=lambda: box.update(fut=svc.submit(m2)),
                             daemon=True)
        t.start()
        # the submitter is parked at the admission gate, not shed
        deadline = time.monotonic() + TIMEOUT
        while svc.metrics().blocked < 1:
            assert time.monotonic() < deadline, "submitter never blocked"
            time.sleep(0.001)
        assert "fut" not in box
        engine.resume.set()                   # m1 completes -> slot frees
        t.join(TIMEOUT)
        _assert_result_matches_analyze(box["fut"].result(timeout=TIMEOUT), m2)
        _assert_result_matches_analyze(f1.result(timeout=TIMEOUT), m1)
        m = svc.metrics()
        assert m.blocked == 1 and m.shed == 0
    finally:
        engine.resume.set()
        svc.close()


def test_rider_on_shed_leader_fails_and_is_not_counted_as_accepted():
    """A duplicate that coalesces onto a leader still waiting at the
    admission gate shares the leader's fate: if the leader is rejected
    (here by close() waking the gate), the rider's future fails too and
    its submit/coalesce counts are backed out — submitted - completed must
    keep tracking real outstanding work."""
    engine = _GatedEngine()
    m1, m2 = _mask((16, 16), seed=130), _mask((16, 16), seed=131)
    svc = YCHGService(engine, ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0,
        max_queue_depth=1, overload_policy="block"))
    f1 = svc.submit(m1)
    assert engine.entered.wait(TIMEOUT)       # m1 holds the only slot
    box = {}

    def leader_submit():
        try:
            svc.submit(m2)
        except RuntimeError as e:
            box["exc"] = e

    t = threading.Thread(target=leader_submit, daemon=True)
    t.start()
    deadline = time.monotonic() + TIMEOUT     # leader parks at the gate
    while svc.metrics().blocked < 1:
        assert time.monotonic() < deadline, "leader never blocked"
        time.sleep(0.001)
    rider = svc.submit(m2.copy())             # coalesces onto parked leader
    assert svc.metrics().coalesced == 1
    # close() wakes the admission gate immediately (the leader fails before
    # any drain), but itself blocks joining the scheduler thread until the
    # engine gate opens — so run it aside and release the engine after the
    # leader's rejection is in hand, keeping the slot occupied throughout
    closer = threading.Thread(target=svc.close, daemon=True)
    closer.start()
    t.join(TIMEOUT)
    assert "closed" in str(box["exc"])
    engine.resume.set()                       # let m1 finish and close drain
    closer.join(TIMEOUT)
    with pytest.raises(RuntimeError, match="closed"):
        rider.result(timeout=TIMEOUT)         # rider shares the rejection
    _assert_result_matches_analyze(f1.result(timeout=TIMEOUT), m1)
    m = svc.metrics()
    # only m1 was ever accepted: the rider's submit/coalesce backed out
    assert m.submitted == 1 and m.completed == 1 and m.coalesced == 0


# ------------------------------------------- engine stream double-buffering


def test_analyze_stream_order_and_parity_through_lookahead():
    """The double-buffered stream (one-item lookahead) still yields strictly
    in order, one result per item, bit-identical per item."""
    rng = np.random.default_rng(60)
    items = [(rng.random((10 + i, 14)) < 0.5).astype(np.uint8)
             for i in range(7)]
    engine = Engine()
    outs = list(engine.analyze_stream(iter(items)))
    assert len(outs) == len(items)
    for item, out in zip(items, outs):
        assert_bit_identical(out.to_summary(), ychg.analyze(jnp.asarray(item)))


def test_analyze_stream_empty_and_singleton():
    engine = Engine()
    assert list(engine.analyze_stream(iter([]))) == []
    img = _mask((9, 9), seed=61)
    (only,) = engine.analyze_stream([img])
    _assert_result_matches_analyze(only, img)


def test_analyze_stream_bad_rank_raises():
    engine = Engine()
    with pytest.raises(ValueError, match="stream items"):
        list(engine.analyze_stream([np.zeros((2, 2, 2, 2), np.uint8)]))


def test_analyze_stream_raising_iterator_still_delivers_prior_results():
    """A source iterator that raises (e.g. a failing loader) must not
    swallow the previous item's computed result either."""
    engine = Engine()
    good = _mask((6, 7), seed=74)

    def loader():
        yield good
        raise OSError("load failed")

    gen = engine.analyze_stream(loader())
    _assert_result_matches_analyze(next(gen), good)
    with pytest.raises(OSError, match="load failed"):
        next(gen)


# ------------------------------------------------------ registry counters


def test_registry_call_counters():
    registry.reset_call_counts()
    assert registry.call_count() == 0
    engine = Engine(YCHGConfig(backend="jax"))
    engine.analyze(np.zeros((4, 4), np.uint8))
    assert registry.call_count("jax") == 1
    assert registry.call_count() == 1
    engine.analyze_batch(np.zeros((2, 4, 4), np.uint8))
    assert registry.call_count("jax") == 2
    assert registry.call_count("fused") == 0
