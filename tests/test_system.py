"""End-to-end behaviour: the paper's pipeline + a small training run."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import analyze_image
from repro.data import modis
from repro.data.synthetic import TokenDataset, TokenDatasetConfig
from repro.models import init_params
from repro.optim import adamw_init
from repro.train import TrainLoop, TrainLoopConfig, make_train_step


def test_paper_pipeline_end_to_end():
    """MODIS-like scene -> two-step yCHG -> consistent stats across backends."""
    img = modis.snowfield(256, seed=3)
    jax_out = analyze_image(img, "jax")
    ser_out = analyze_image(img, "serial")
    pal_out = analyze_image(img, "pallas")
    for k in ("runs", "births", "deaths", "n_hyperedges"):
        np.testing.assert_array_equal(jax_out[k], ser_out[k])
        np.testing.assert_array_equal(jax_out[k], pal_out[k])
    assert jax_out["n_hyperedges"] > 0


def test_hyperedge_knob_is_exact():
    """striped() hits the requested hyperedge count exactly (paper knob b)."""
    for n in (1, 147, 500):
        img = modis.striped(256, n)
        out = analyze_image(img, "jax")
        assert int(out["n_hyperedges"]) == n


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=128, tie_embeddings=True,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=64,
    )


def test_loss_decreases_over_training():
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ds = TokenDataset(TokenDatasetConfig(vocab_size=128, seq_len=32,
                                         global_batch=8, n_patterns=4))
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup_steps=5,
                                   total_steps=60))
    losses = []
    for i in range(60):
        b = ds.batch(i)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_train_loop_resume(tmp_path):
    """Kill/restart: resumed run continues from the checkpointed step."""
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ds = TokenDataset(TokenDatasetConfig(vocab_size=128, seq_len=16,
                                         global_batch=4))
    step = jax.jit(make_train_step(cfg, total_steps=30))

    def batches(start):
        return ({k: jnp.asarray(v) for k, v in ds.batch(i).items()}
                for i in range(start, 100))

    loop = TrainLoop(step, TrainLoopConfig(total_steps=10, ckpt_dir=str(tmp_path),
                                           ckpt_every=5, log_every=100))
    p1, o1, s1 = loop.run(params, opt, batches(0))
    assert s1 == 10
    # "crash" and restart from checkpoint
    loop2 = TrainLoop(step, TrainLoopConfig(total_steps=20, ckpt_dir=str(tmp_path),
                                            ckpt_every=5, log_every=100))
    p2, o2, start = loop2.resume_or_init(params, opt)
    assert start == 10
    p3, o3, s3 = loop2.run(p2, o2, batches(start), start_step=start)
    assert s3 == 20


def test_grad_accum_matches_single_batch():
    """grad_accum=2 must equal one big batch (same update direction)."""
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    ds = TokenDataset(TokenDatasetConfig(vocab_size=128, seq_len=16,
                                         global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    s1 = jax.jit(make_train_step(cfg))
    s2 = jax.jit(make_train_step(cfg, grad_accum=2))
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p2, _, m2 = s2(params, adamw_init(params), batch)
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2))
    )
    assert d < 5e-3, d
