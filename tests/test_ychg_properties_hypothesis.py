"""Hypothesis fuzzing of the same yCHG invariants as the seeded fallback.

``hypothesis`` is an optional test dependency: this whole module skips on a
bare install (tier-1 must collect with zero errors without it), while
test_ychg_properties.py keeps the invariants covered via its seeded corpus.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from ychg_invariants import ALL_CHECKS

masks = hnp.arrays(
    dtype=np.uint8,
    shape=st.tuples(st.integers(1, 40), st.integers(1, 40)),
    elements=st.integers(0, 1),
)


@pytest.mark.parametrize("name", sorted(ALL_CHECKS))
@given(img=masks)
@settings(max_examples=25, deadline=None)
def test_invariant_fuzzed(name, img):
    ALL_CHECKS[name](img)
