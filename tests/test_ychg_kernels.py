"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracle (ref.py)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.ychg_colscan import colscan_runs_pallas, colscan_runs_streamed

SHAPES = [(1, 1), (7, 5), (16, 128), (33, 200), (128, 384), (257, 131), (5, 1024)]
DTYPES = [np.uint8, np.int32, np.bool_, np.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_colscan_matches_ref(shape, dtype):
    rng = np.random.default_rng(hash((shape, str(dtype))) % 2**32)
    img = (rng.random(shape) < 0.45).astype(dtype)
    got = np.asarray(ops.colscan_runs(jnp.asarray(img)))
    want = np.asarray(ref.colscan_runs_ref(jnp.asarray(img)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_w", [128, 256])
def test_colscan_block_width_invariance(block_w):
    rng = np.random.default_rng(0)
    img = (rng.random((64, 300)) < 0.5).astype(np.uint8)
    got = np.asarray(colscan_runs_pallas(jnp.asarray(img), block_w=block_w))
    want = np.asarray(ref.colscan_runs_ref(jnp.asarray(img)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_h", [4, 16, 64])
def test_streamed_kernel_carry(block_h):
    """Carry across H-blocks must not double count runs spanning a boundary."""
    rng = np.random.default_rng(1)
    img = (rng.random((130, 140)) < 0.6).astype(np.uint8)
    got = np.asarray(
        colscan_runs_streamed(jnp.asarray(img), block_h=block_h)
    )
    want = np.asarray(ref.colscan_runs_ref(jnp.asarray(img)))
    np.testing.assert_array_equal(got, want)


def test_streamed_boundary_run():
    """A single run crossing every block boundary (all-ones column)."""
    img = np.ones((64, 8), np.uint8)
    got = np.asarray(colscan_runs_streamed(jnp.asarray(img), block_h=16))
    np.testing.assert_array_equal(got, np.ones(8, np.int32))


@pytest.mark.parametrize("w", [1, 127, 128, 129, 300])
def test_transitions_kernel(w):
    rng = np.random.default_rng(w)
    runs = jnp.asarray(rng.integers(0, 5, size=(w,)).astype(np.int32))
    t, b, d = ops.transitions(runs)
    tr, br, dr = ref.transitions_ref(runs)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(tr))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))


def test_analyze_full_pipeline():
    rng = np.random.default_rng(5)
    img = (rng.random((100, 333)) < 0.4).astype(np.uint8)
    out = ops.analyze(jnp.asarray(img))
    want = ref.analyze_ref(jnp.asarray(img))
    np.testing.assert_array_equal(np.asarray(out["runs"]), np.asarray(want["runs"]))
    assert int(out["n_hyperedges"]) == int(want["n_hyperedges"])


# ----------------------------------------------------------------- bit-packed

from repro.kernels.ychg_packed import pack_rows, packed_analyze, packed_colscan


@pytest.mark.parametrize("shape", SHAPES)
def test_packed_colscan_matches_ref(shape):
    rng = np.random.default_rng(hash(shape) % 2**32)
    img = (rng.random(shape) < 0.45).astype(np.uint8)
    got = np.asarray(packed_colscan(pack_rows(jnp.asarray(img))))
    want = np.asarray(ref.colscan_runs_ref(jnp.asarray(img)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", [(33, 200), (128, 384), (257, 131)])
def test_packed_fused_analyze(shape):
    """Fused step1+2 incl. tile-boundary stitching."""
    rng = np.random.default_rng(7)
    img = (rng.random(shape) < 0.5).astype(np.uint8)
    got = packed_analyze(jnp.asarray(img))
    want = ref.analyze_ref(jnp.asarray(img))
    for k in ("runs", "births", "deaths"):
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))
    assert int(got["n_hyperedges"]) == int(want["n_hyperedges"])


def test_pack_rows_bit_layout():
    img = np.zeros((9, 2), np.uint8)
    img[0, 0] = 1   # bit 0 of byte 0
    img[7, 0] = 1   # bit 7 of byte 0
    img[8, 1] = 1   # bit 0 of byte 1
    pk = np.asarray(pack_rows(jnp.asarray(img)))
    assert pk.shape == (2, 2)
    assert pk[0, 0] == 0x81 and pk[1, 1] == 0x01
