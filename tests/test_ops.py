"""Multi-op platform suite.

Covers the (op, platform) registry and the two new operators end to end:
  * typed op errors — unknown ops raise ``UnknownOpError`` naming the
    registered ops, never a bare ``KeyError``;
  * registration is live — a backend registered for an op after an engine
    was built wins the very next resolution (generation bump);
  * platform fallback — an op whose backends claim no current platform
    resolves to its best batch-capable backend with a ``RuntimeWarning``;
  * ccl / denoise parity — jnp reference vs Pallas kernel bit-identical
    on ragged corpora, ccl vs a pure-Python BFS oracle, and both ops
    pad-invariant (zero padding never changes the native region);
  * pipelines — spec validation errors, and the device-resident compound
    request pinned bit-identical to issuing the stages as separate
    requests, at the engine AND service layers;
  * per-op serving — cache entries namespaced by op, per-op bucket
    ladders and max_batch from ``ServiceConfig``.
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.engine import (
    Engine,
    UnknownOpError,
    YCHGConfig,
    registry,
    resolve,
)
from repro.engine.ops import (
    get_op,
    op_names,
    pipeline_op_key,
    split_pipeline_key,
    validate_pipeline,
)
from repro.kernels import ccl as cclmod
from repro.kernels import denoise as dnmod
from repro.service import Service, ServiceConfig
from repro.service.cache import make_key


def _masks(shapes, seed=0, density=0.5):
    rng = np.random.default_rng(seed)
    return [(rng.random(s) < density).astype(np.uint8) for s in shapes]


RAGGED = [(1, 1), (1, 7), (6, 1), (17, 23), (20, 17), (33, 64)]


# ----------------------------------------------------------- op registry


def test_builtin_ops_registered_everywhere():
    assert {"ychg", "ccl", "denoise"} <= set(op_names())
    assert {"ychg", "ccl", "denoise"} <= set(registry.registered_ops())
    for op in ("ccl", "denoise"):
        assert set(registry.backend_names(op)) == {"jax", "pallas"}


def test_unknown_op_is_a_typed_error_naming_registered_ops():
    with pytest.raises(UnknownOpError, match="ychg"):
        get_op("warp")
    with pytest.raises(UnknownOpError, match="warp"):
        resolve("auto", platform="cpu", op="warp")
    # an engine surfaces the same typed error, not a KeyError
    with pytest.raises(UnknownOpError):
        Engine().analyze(np.zeros((4, 4), np.uint8), op="warp")


def test_register_backend_for_op_is_live_immediately():
    """Registering a higher-priority ccl backend after the engine resolved
    once must win the next resolution (resolve.cache_clear + generation
    bump), and unregistering restores the old pick."""
    fixed = cclmod.labels(jnp.ones((1, 2, 3), jnp.uint8))
    eng = Engine(YCHGConfig(backend="auto"))
    assert eng.resolve_backend(op="ccl") == "jax"   # prime caches
    gen = registry.generation()
    registry.register_backend(registry.BackendSpec(
        name="_test_ccl_stub", op="ccl", run=lambda x, c: fixed,
        supports_batch=True, supports_mesh=False, device_kinds=("cpu",),
        priority={"cpu": 999},
    ))
    try:
        assert registry.generation() > gen
        assert eng.resolve_backend(op="ccl") == "_test_ccl_stub"
        # the ychg namespace is untouched by a ccl registration
        assert "_test_ccl_stub" not in registry.backend_names("ychg")
    finally:
        registry.unregister_backend("_test_ccl_stub", op="ccl")
    assert eng.resolve_backend(op="ccl") == "jax"


def test_op_with_no_backend_for_platform_warns_and_falls_back():
    """An op registered only for some other platform resolves with a
    clear RuntimeWarning — never a KeyError."""
    registry.register_backend(registry.BackendSpec(
        name="_test_tpu_only", op="_toyop",
        run=lambda x, c: cclmod.labels(x), supports_batch=True,
        supports_mesh=False, device_kinds=("tpu",), priority={"tpu": 10},
    ))
    try:
        with pytest.warns(RuntimeWarning, match="falling back to backend"):
            spec = resolve("auto", platform="cpu", op="_toyop")
        assert spec.name == "_test_tpu_only"
    finally:
        registry.unregister_backend("_test_tpu_only", op="_toyop")
    with pytest.raises(UnknownOpError):
        resolve("auto", platform="cpu", op="_toyop")


# ------------------------------------------------------------- ccl parity


def _bfs_labels(mask):
    """Pure-Python 4-neighbour CCL oracle: row-major first-encounter
    numbering, which is exactly the canonical min-linear-index rank."""
    h, w = mask.shape
    out = np.zeros((h, w), np.int32)
    n = 0
    for i in range(h):
        for j in range(w):
            if mask[i, j] and not out[i, j]:
                n += 1
                stack = [(i, j)]
                out[i, j] = n
                while stack:
                    y, x = stack.pop()
                    for dy, dx in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                        yy, xx = y + dy, x + dx
                        if (0 <= yy < h and 0 <= xx < w and mask[yy, xx]
                                and not out[yy, xx]):
                            out[yy, xx] = n
                            stack.append((yy, xx))
    return out, n


@pytest.mark.parametrize("shape", RAGGED)
def test_ccl_reference_matches_bfs_oracle(shape):
    (mask,) = _masks([shape], seed=sum(shape))
    got = cclmod.labels(jnp.asarray(mask)[None])
    want_lab, want_n = _bfs_labels(mask)
    np.testing.assert_array_equal(np.asarray(got.labels[0]), want_lab)
    assert int(got.n_components[0]) == want_n


def test_ccl_pallas_bit_identical_to_reference():
    rng = np.random.default_rng(3)
    stack = (rng.random((4, 24, 31)) < 0.5).astype(np.uint8)
    a = cclmod.labels(jnp.asarray(stack))
    b = cclmod.labels_pallas(jnp.asarray(stack))
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))
    np.testing.assert_array_equal(np.asarray(a.n_components),
                                  np.asarray(b.n_components))


def test_ccl_is_pad_invariant():
    """Zero padding to a larger canvas starts no components and never
    renumbers the native region (row-major first encounter preserved)."""
    (mask,) = _masks([(13, 19)], seed=5)
    base = cclmod.labels(jnp.asarray(mask)[None])
    padded = np.zeros((1, 20, 32), np.uint8)
    padded[0, :13, :19] = mask
    pad = cclmod.labels(jnp.asarray(padded))
    np.testing.assert_array_equal(np.asarray(pad.labels[0, :13, :19]),
                                  np.asarray(base.labels[0]))
    assert np.all(np.asarray(pad.labels)[0, 13:, :] == 0)
    assert np.all(np.asarray(pad.labels)[0, :, 19:] == 0)
    assert int(pad.n_components[0]) == int(base.n_components[0])


# --------------------------------------------------------- denoise parity


def test_denoise_pallas_bit_identical_to_reference():
    rng = np.random.default_rng(4)
    stack = rng.random((3, 22, 27)).astype(np.float32)
    a = dnmod.denoise(jnp.asarray(stack))
    b = dnmod.denoise_pallas(jnp.asarray(stack))
    np.testing.assert_array_equal(np.asarray(a.image), np.asarray(b.image))
    assert np.asarray(a.image).dtype == np.float32


def test_denoise_is_pad_invariant():
    """The 3x3 window zero-pads at borders, so padding the canvas with
    zeros reproduces the native region exactly."""
    rng = np.random.default_rng(6)
    img = rng.random((14, 18)).astype(np.float32)
    base = dnmod.denoise(jnp.asarray(img)[None])
    padded = np.zeros((1, 20, 24), np.float32)
    padded[0, :14, :18] = img
    pad = dnmod.denoise(jnp.asarray(padded))
    # interior rows/cols are window-identical; the former border rows see
    # a zero neighbourhood either way
    np.testing.assert_array_equal(np.asarray(pad.image[0, :13, :17]),
                                  np.asarray(base.image[0, :13, :17]))


# -------------------------------------------------- engine per-op dispatch


@pytest.mark.parametrize("op", ["ccl", "denoise"])
def test_engine_dispatches_new_ops_bit_identical(op):
    rng = np.random.default_rng(7)
    stack = (rng.random((5, 18, 25)) < 0.5).astype(np.uint8)
    eng = Engine()
    got = eng.analyze_batch(stack, op=op).to_host()
    spec = get_op(op)
    want = spec.from_summary(spec.reference(jnp.asarray(stack)), True)
    for field, arr in want.to_host().items():
        np.testing.assert_array_equal(got[field], np.asarray(arr),
                                      err_msg=field)


@pytest.mark.parametrize("op", ["ccl", "denoise"])
def test_engine_meshed_new_ops_bit_identical(op):
    from repro.sharding import make_batch_mesh

    rng = np.random.default_rng(8)
    stack = (rng.random((3, 16, 21)) < 0.5).astype(np.uint8)  # ragged vs mesh
    mesh = make_batch_mesh()
    eng = Engine(YCHGConfig(backend="auto"), mesh=mesh)
    got = eng.analyze_batch(stack, op=op)
    assert got.batch_size == 3
    spec = get_op(op)
    want = spec.from_summary(spec.reference(jnp.asarray(stack)), True)
    for field, arr in want.to_host().items():
        np.testing.assert_array_equal(got.to_host()[field], np.asarray(arr),
                                      err_msg=field)


# --------------------------------------------------------------- pipelines


def test_pipeline_spec_validation():
    assert validate_pipeline(["denoise", "ychg"]) == ("denoise", "ychg")
    assert pipeline_op_key(["denoise", "ychg"]) == "denoise+ychg"
    assert split_pipeline_key("denoise+ychg") == ("denoise", "ychg")
    assert split_pipeline_key("ychg") == ("ychg",)
    with pytest.raises(ValueError):
        validate_pipeline([])
    with pytest.raises(UnknownOpError):
        validate_pipeline(["denoise", "warp"])
    # ychg has no chain_field: it can only terminate a pipeline
    with pytest.raises(ValueError, match="terminal"):
        validate_pipeline(["ychg", "ccl"])


def test_engine_pipeline_equals_sequential_dispatch():
    rng = np.random.default_rng(9)
    stack = rng.random((4, 20, 28)).astype(np.float32)
    eng = Engine()
    piped = eng.run_pipeline(stack, ["denoise", "ychg"]).to_host()
    mid = eng.analyze_batch(stack, op="denoise")
    want = eng.analyze_batch(mid.image, op="ychg").to_host()
    for field, arr in want.items():
        np.testing.assert_array_equal(piped[field], np.asarray(arr),
                                      err_msg=field)


def test_service_pipeline_equals_separate_requests_ragged():
    """The compound request through the bucketed service — padded canvas,
    inter-stage re-zeroing — pinned bit-identical to feeding stage 1's
    cropped output back in as a fresh stage 2 request, across ragged
    shapes sharing one bucket."""
    rng = np.random.default_rng(10)
    imgs = [rng.random(s).astype(np.float32)
            for s in ((30, 30), (17, 25), (32, 9))]
    cfg = ServiceConfig(bucket_sides=(32,), max_batch=4, max_delay_ms=1.0)
    with Service(Engine(), cfg) as svc:
        for img in imgs:
            piped = svc.pipeline(img, ["denoise", "ychg"],
                                 timeout=600).to_host()
            mid = svc.submit(img, op="denoise").result(timeout=600)
            want = svc.submit(np.asarray(mid.to_host()["image"]),
                              op="ychg").result(timeout=600).to_host()
            for field, arr in want.items():
                np.testing.assert_array_equal(
                    np.asarray(piped[field]), np.asarray(arr), err_msg=field)


def test_pipeline_stage_spans_and_histograms_recorded():
    cfg = ServiceConfig(bucket_sides=(16,), max_batch=2)
    with Service(Engine(), cfg) as svc:
        svc.pipeline(np.random.default_rng(0).random((12, 12))
                     .astype(np.float32), ["denoise", "ychg"], timeout=600)
        m = svc.metrics()
    stages = {dict(labels).get("stage") for labels, _snap in m.stage_hists}
    assert {"pipeline.denoise", "pipeline.ychg"} <= stages


# ------------------------------------------------------------ per-op serving


def test_cache_entries_are_namespaced_by_op():
    (mask,) = _masks([(16, 16)], seed=11)
    cfg = YCHGConfig()
    assert make_key(mask, "jax", cfg, op="ychg") != \
        make_key(mask, "jax", cfg, op="ccl")
    with Service(Engine(), ServiceConfig(bucket_sides=(16,))) as svc:
        svc.submit(mask, op="ychg").result(timeout=600)
        svc.submit(mask, op="ccl").result(timeout=600)   # no cross-op hit
        m1 = svc.metrics()
        svc.submit(mask, op="ccl").result(timeout=600)   # same-op repeat
        m2 = svc.metrics()
    assert m1.cache_misses == 2 and m1.cache_hits == 0
    assert m2.cache_hits == 1


def test_per_op_bucket_ladder_and_max_batch():
    cfg = ServiceConfig(bucket_sides=(64, 128), max_batch=8,
                        op_bucket_sides=(("ccl", (32,)),),
                        op_max_batch=(("ccl", 2),))
    assert cfg.bucket_sides_for("ccl") == (32,)
    assert cfg.bucket_sides_for("ychg") == (64, 128)
    assert cfg.max_batch_for("ccl") == 2
    assert cfg.max_batch_for("ychg") == 8
    (mask,) = _masks([(20, 20)], seed=12)
    with Service(Engine(), cfg) as svc:
        svc.submit(mask, op="ccl").result(timeout=600)
        m = svc.metrics()
    # a 20x20 ccl request lands in ccl's own 32 ladder, not the default 64
    assert (1, 32, 32) in m.compiled_shapes


def test_submit_rejects_pipeline_keys_pointing_at_submit_pipeline():
    with Service(Engine(), ServiceConfig(bucket_sides=(16,))) as svc:
        with pytest.raises(ValueError, match="submit_pipeline"):
            svc.submit(np.zeros((8, 8), np.uint8), op="denoise+ychg")
