"""Per assigned architecture: reduced config of the same family, one
forward/train step on CPU, output shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.archs import smoke_config
from repro.models import (
    count_params,
    decode_step,
    init_cache,
    init_params,
)
from repro.optim import adamw_init
from repro.train.step import make_train_step

ARCHS = list_archs()

# full-config param counts must land near the advertised sizes
EXPECTED_B = {
    "qwen2-0.5b": (0.3, 0.7),
    "command-r-35b": (25, 40),
    "minicpm3-4b": (3, 5),
    "qwen3-4b": (3, 5),
    "jamba-v0.1-52b": (45, 60),
    "rwkv6-3b": (2.5, 4),
    "llava-next-34b": (30, 40),
    "phi3.5-moe-42b-a6.6b": (38, 46),
    "llama4-maverick-400b-a17b": (350, 450),
    "musicgen-medium": (1.0, 2.2),
}


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_param_count(name):
    lo, hi = EXPECTED_B[name]
    n = count_params(get_config(name)) / 1e9
    assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo},{hi}]"


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step_and_decode(name):
    cfg = smoke_config(name)
    params = init_params(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, total_steps=10, warmup_steps=2)
    opt = adamw_init(params)
    b, s = 2, 32
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.frontend != "none" and cfg.frontend_tokens:
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.d_model)), jnp.float32
        )
    p2, o2, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"])), name
    assert float(m["grad_norm"]) > 0
    cache = init_cache(cfg, b, s)
    lg, cache2 = decode_step(p2, cfg, cache, batch["tokens"][:, :1], jnp.int32(0))
    assert lg.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), name
