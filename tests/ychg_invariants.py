"""Shared yCHG invariant checks (paper §1-2) + a deterministic mask corpus.

Two test modules consume these:

  test_ychg_properties.py             — seeded-random pure-pytest fallback;
                                        always runs, even on a bare install.
  test_ychg_properties_hypothesis.py  — the same invariants driven by
                                        hypothesis fuzzing; skipped when
                                        hypothesis is not installed.

Each check takes one (H, W) uint8/bool mask and raises on violation, so the
same functions serve as hypothesis properties and as plain assertions over
the corpus.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import regions, serial, ychg


# --------------------------------------------------------------- invariants


def check_parallel_equals_serial(img: np.ndarray) -> None:
    """The paper's correctness claim: parallel step 1 == scalar walk, exactly."""
    got = np.asarray(ychg.column_runs(jnp.asarray(img)))
    want = serial.column_runs_scalar(img)
    np.testing.assert_array_equal(got, want)


def check_conservation(img: np.ndarray) -> None:
    """births - deaths telescopes to the last column's run count."""
    s = ychg.analyze(jnp.asarray(img))
    assert bool(ychg.check_conservation(s))
    # restated on host so the jnp reduction cannot hide a sign bug:
    b = int(np.asarray(s.births).sum())
    d = int(np.asarray(s.deaths).sum())
    assert b - d == int(np.asarray(s.runs)[-1])


def check_hyperedge_count_horizontal_flip(img: np.ndarray) -> None:
    a = int(ychg.hyperedge_count(jnp.asarray(img)))
    b = int(ychg.hyperedge_count(jnp.asarray(img[:, ::-1].copy())))
    assert a == b


def check_runs_vertical_flip(img: np.ndarray) -> None:
    """Reversing each column preserves its maximal-run count."""
    a = np.asarray(ychg.column_runs(jnp.asarray(img)))
    b = np.asarray(ychg.column_runs(jnp.asarray(img[::-1, :].copy())))
    np.testing.assert_array_equal(a, b)


def check_row_duplication_preserves_runs(img: np.ndarray) -> None:
    """Doubling height by repeating rows keeps run counts (y-convexity is
    about connectivity, not thickness)."""
    a = np.asarray(ychg.column_runs(jnp.asarray(img)))
    b = np.asarray(ychg.column_runs(jnp.asarray(np.repeat(img, 2, axis=0))))
    np.testing.assert_array_equal(a, b)


def check_blank_column_padding(img: np.ndarray) -> None:
    """Appending background columns adds no runs and no hyperedges."""
    padded = np.pad(img, ((0, 0), (0, 3)))
    a = int(ychg.hyperedge_count(jnp.asarray(img)))
    b = int(ychg.hyperedge_count(jnp.asarray(padded)))
    assert a == b


def check_runs_bounded_by_half_height(img: np.ndarray) -> None:
    runs = np.asarray(ychg.column_runs(jnp.asarray(img)))
    h = img.shape[0]
    assert (runs >= 0).all() and (runs <= (h + 1) // 2).all()


def check_decomposition_valid(img: np.ndarray) -> None:
    """regions.decompose: (a) covers the ROI exactly, (b) each hyperedge is
    y-convex over consecutive columns, (c) count >= the poster's signal."""
    labels, n = regions.label_image(img)
    np.testing.assert_array_equal(labels > 0, img != 0)
    for e in regions.decompose(img):
        cols = [r.col for r in e.runs]
        assert len(cols) == len(set(cols))                  # y-convex
        assert cols == list(range(cols[0], cols[-1] + 1))   # consecutive
    count_model = int(ychg.hyperedge_count(jnp.asarray(img)))
    assert n >= count_model


def check_births_bound_chain_heads(img: np.ndarray) -> None:
    """Per-column tie between the transition signal and the materialised
    decomposition: the number of hyperedge chains *starting* at column j is
    at least births[j] (the count model's lower bound — a chain head is a run
    with no one-to-one left partner, and #heads >= runs[j] - runs[j-1])."""
    s = ychg.analyze(jnp.asarray(img))
    births = np.asarray(s.births)
    heads = np.zeros(img.shape[1], dtype=np.int64)
    for e in regions.decompose(img):
        heads[e.runs[0].col] += 1
    assert (heads >= births).all(), (heads, births)


def check_area_estimation(img: np.ndarray) -> None:
    """ref [3]'s application: area via decomposition == pixel count."""
    assert regions.total_area(img) == int((img != 0).sum())


SUMMARY_FIELDS = ("runs", "cut_vertices", "transitions", "births", "deaths",
                  "n_hyperedges", "n_transitions")


def assert_bit_identical(got: ychg.YCHGSummary, want: ychg.YCHGSummary) -> None:
    """The parity bar: same dtypes, shapes, and values on every field."""
    for f in SUMMARY_FIELDS:
        g, w = getattr(got, f), getattr(want, f)
        assert g.dtype == w.dtype, f"{f}: {g.dtype} != {w.dtype}"
        assert g.shape == w.shape, f"{f}: {g.shape} != {w.shape}"
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=f)


def check_fused_kernel_parity(img: np.ndarray) -> None:
    """The fused single-launch Pallas kernel is bit-identical to core.ychg."""
    from repro.kernels import ops as kops

    assert_bit_identical(kops.analyze_fused(jnp.asarray(img)),
                         ychg.analyze(jnp.asarray(img)))


ALL_CHECKS = {
    "parallel_equals_serial": check_parallel_equals_serial,
    "conservation": check_conservation,
    "horizontal_flip": check_hyperedge_count_horizontal_flip,
    "vertical_flip_runs": check_runs_vertical_flip,
    "row_duplication": check_row_duplication_preserves_runs,
    "blank_column_padding": check_blank_column_padding,
    "runs_bounded": check_runs_bounded_by_half_height,
    "decomposition_valid": check_decomposition_valid,
    "births_bound_chain_heads": check_births_bound_chain_heads,
    "area_estimation": check_area_estimation,
    "fused_kernel_parity": check_fused_kernel_parity,
}


# ------------------------------------------------------------------- corpus


def structured_masks() -> list[np.ndarray]:
    """Deterministic adversarial masks: degenerate shapes + the documented
    branch/merge and same-count reconnection cases."""
    donut = np.array([[1, 1, 1], [1, 0, 1], [1, 1, 1]], np.uint8)  # branch+merge
    # same-count reconnection: runs 2 -> 2 but every chain breaks at col 1
    # (no row overlap). The count signal sees NO transition there; the
    # materialised decomposition must still split (documented limitation).
    reconnect = np.zeros((7, 2), np.uint8)
    reconnect[[0, 4], 0] = 1
    reconnect[[2, 6], 1] = 1
    checker = np.indices((8, 8)).sum(axis=0) % 2
    return [
        np.zeros((1, 1), np.uint8),
        np.ones((1, 1), np.uint8),
        np.zeros((5, 7), np.uint8),           # all background
        np.ones((5, 7), np.uint8),            # all foreground
        np.ones((40, 1), np.uint8),           # single column
        np.ones((1, 40), np.uint8),           # single row
        donut,
        reconnect,
        checker.astype(np.uint8),
    ]


def random_masks(n: int = 24, seed: int = 20130610) -> list[np.ndarray]:
    """Seeded random masks over the same shape/density space the hypothesis
    strategy samples (1..40 per side, density 5%..95%)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        h = int(rng.integers(1, 41))
        w = int(rng.integers(1, 41))
        p = float(rng.uniform(0.05, 0.95))
        out.append((rng.random((h, w)) < p).astype(np.uint8))
    return out


def corpus() -> list[np.ndarray]:
    return structured_masks() + random_masks()
