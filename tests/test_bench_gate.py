"""`benchmarks/check_bench_regression.py` suite: the CI bench gate.

The gate is a subprocess contract (CI invokes it and trusts the exit
code), so these tests run it as a subprocess and assert on exit codes
and output — no wall clocks, no engine, just JSON files in tmp_path.

Two families:

  * **missing/malformed sections fail loudly** — the PR 10 bugfix. The
    pre-fix gate compared an EMPTY baseline against anything and
    printed "bench gate passed" (exit 0), and crashed with a bare
    traceback on a non-object section file. Both are now clean FAIL
    lines and a nonzero exit: a gate that silently passes on a
    malformed archive is worse than no gate.
  * **the slo section** — the committed ``BENCH_slo.json`` passes, the
    ``--simulate-regression`` self-test trips nonzero, and pointing
    ``--slo`` at an archive without the slo scenarios fails.
"""

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
GATE = REPO / "benchmarks" / "check_bench_regression.py"


def run_gate(*args: str) -> "subprocess.CompletedProcess[str]":
    return subprocess.run(
        [sys.executable, str(GATE), *args],
        capture_output=True, text=True, cwd=REPO, timeout=60)


def write_json(path: Path, obj) -> str:
    path.write_text(json.dumps(obj))
    return str(path)


# ------------------------- missing/malformed sections (PR 10 bugfix)


def test_empty_baseline_fails_instead_of_passing(tmp_path):
    """Pre-fix: zero baseline scenarios meant zero checks and a clean
    'bench gate passed' exit 0 — the silent-pass bug this PR fixes."""
    empty = {"mode": "quick", "scenarios": []}
    baseline = write_json(tmp_path / "baseline.json", empty)
    fresh = write_json(tmp_path / "fresh.json", empty)
    r = run_gate("--baseline", baseline, "--fresh", fresh)
    assert r.returncode != 0
    assert "no scenarios" in r.stdout


def test_non_object_section_file_fails_cleanly(tmp_path):
    """A section file holding a JSON array (not an object) must be a
    FAIL line and exit 1 — pre-fix it was an AttributeError traceback."""
    bad = write_json(tmp_path / "fleet.json", [1, 2, 3])
    r = run_gate("--fleet", bad)
    assert r.returncode != 0
    assert "not a JSON object" in r.stdout
    assert "Traceback" not in r.stderr


def test_unreadable_section_file_fails_cleanly(tmp_path):
    r = run_gate("--slo", str(tmp_path / "does_not_exist.json"))
    assert r.returncode != 0
    assert "cannot read" in r.stdout
    assert "Traceback" not in r.stderr


def test_invalid_json_section_file_fails_cleanly(tmp_path):
    bad = tmp_path / "scene.json"
    bad.write_text("{not json")
    r = run_gate("--scene", str(bad))
    assert r.returncode != 0
    assert "not valid JSON" in r.stdout
    assert "Traceback" not in r.stderr


# --------------------------------------------------- the slo section


def test_committed_slo_archive_passes():
    r = run_gate("--slo", str(REPO / "BENCH_slo.json"))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "slo gate" in r.stdout and "ok" in r.stdout


def test_slo_simulate_regression_self_test_trips():
    """CI's negative self-test: the degraded archive MUST exit nonzero,
    tripping every slo check family."""
    r = run_gate("--slo", str(REPO / "BENCH_slo.json"),
                 "--simulate-regression")
    assert r.returncode != 0
    assert "batch_sheds 0" in r.stdout
    assert "quota_sheds 0" in r.stdout
    assert "dead_sheds 0" in r.stdout


def test_slo_section_missing_scenarios_fails(tmp_path):
    """An archive without the slo scenarios (e.g. the wrong BENCH file)
    must fail each required row by name, not pass by vacuity."""
    not_slo = write_json(tmp_path / "slo.json",
                         {"scenarios": [{"scenario": "something_else"}]})
    r = run_gate("--slo", not_slo)
    assert r.returncode != 0
    for row in ("traffic_classes", "deadline_shed", "tenant_quota"):
        assert f"no {row} scenario" in r.stdout


def test_other_sections_still_pass_on_committed_archives():
    """The PR 10 rework of main() must not break the existing section
    gates against their committed archives."""
    r = run_gate("--fleet", str(REPO / "BENCH_fleet.json"),
                 "--scene", str(REPO / "BENCH_scene.json"),
                 "--ops", str(REPO / "BENCH_ops.json"))
    assert r.returncode == 0, r.stdout + r.stderr
