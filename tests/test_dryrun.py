"""Dry-run machinery tests.

The analytic cost model is validated against XLA cost_analysis on loop-free
lowerings (scan_layers=False, seq <= attn_chunk, remat=none, 1 device). The
full 512-device dry-run runs as a subprocess (device count is locked at
first jax init, so it cannot run in this process) — marked slow; the real
40-cell sweep is driven by `python -m repro.launch.dryrun` (EXPERIMENTS.md).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import smoke_config
from repro.configs.base import ShapeConfig
from repro.launch import analytic, roofline
from repro.train.step import make_train_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _measured_train_flops(cfg, shape):
    step = make_train_step(cfg)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend != "none" and cfg.frontend_tokens:
        batch["frontend_embeds"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    from repro.models import abstract_params
    from repro.optim.adamw import abstract_opt_state

    p = abstract_params(cfg)
    compiled = jax.jit(step).lower(p, abstract_opt_state(p), batch).compile()
    return float(roofline.cost_dict(compiled).get("flops", 0.0))


@pytest.mark.xfail(
    strict=False,
    reason="seed triage: analytic-vs-XLA flops tolerance drifts with the "
    "jax/XLA version (the seed image failed on cost_analysis() returning a "
    "list; fixed, but the 2x tolerance stays advisory — tracking: ROADMAP "
    "'Pre-existing (seed)')",
)
@pytest.mark.parametrize("name", ["qwen2-0.5b", "phi3.5-moe-42b-a6.6b",
                                  "rwkv6-3b", "jamba-v0.1-52b"])
def test_analytic_flops_close_to_measured(name):
    """Loop-free smoke config: analytic within 2x of measured (XLA fuses some
    elementwise work into flops it doesn't count, transcendental weights etc.;
    the matmul-dominated terms must line up)."""
    cfg = smoke_config(name).scaled(scan_layers=False, remat="none")
    shape = ShapeConfig("probe", "train", 32, 4)
    measured = _measured_train_flops(cfg, shape)
    # analytic models remat multiplier 3x for remat=none (fwd + 2x bwd)
    a = analytic.flops(cfg, shape)
    assert measured > 0
    ratio = a / measured
    assert 0.5 < ratio < 2.0, f"{name}: analytic/measured = {ratio:.2f}"


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = f32[512,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%y), replica_groups=[16,16]<=[16,16]T(1,0)
  %cp = s32[64]{0} collective-permute(%z), source_target_pairs={{0,1},{1,0}}
  %rs = f32[32]{0} reduce-scatter(%w), replica_groups=[2,8]<=[16]
"""
    out = roofline.collective_bytes(hlo)
    # f32 clamped to bf16: 512*128*2 = 131072; ring (g-1)/g with g=16
    assert abs(out["all-gather"] - 131072 * 15 / 16) < 1
    assert abs(out["all-reduce"] - 1024 * 2 * 2 * 15 / 16) < 1
    assert out["collective-permute"] == 64 * 4  # ints not clamped
    assert abs(out["reduce-scatter"] - 32 * 2 * 7) < 1
    assert out["_count_all-reduce"] == 1


def test_extrapolation():
    m1 = {"flops": 10.0, "total": 4.0}
    m2 = {"flops": 16.0, "total": 7.0}
    out = roofline.extrapolate(m1, m2, 10)
    assert out["flops"] == 10.0 - 6.0 + 10 * 6.0
    assert out["total"] == 4.0 - 3.0 + 10 * 3.0


def test_roofline_terms_and_dominant():
    t = roofline.terms(flops_global=1e15, bytes_global=1e12,
                       coll_bytes_per_partition=1e9, n_partitions=256)
    assert t["compute_s"] == pytest.approx(1e15 / (256 * roofline.PEAK_FLOPS))
    assert roofline.dominant(t) in ("compute_s", "memory_s", "collective_s")


@pytest.mark.slow
def test_dryrun_subprocess_one_cell():
    # Seed triage note: this cell failed on the seed image because
    # cost_analysis() returned a list on that jax version; fixed via the
    # shared roofline.cost_dict compat. Kept strict (no xfail) — it is a
    # deterministic end-to-end gate, and silently xfailing it would mask
    # the exact regression class that was just fixed.
    """End-to-end dry-run of the cheapest cell in a fresh process."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--mesh", "single",
         "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
