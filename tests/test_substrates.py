"""Optimizer, checkpointer, data pipeline, serve engine, sharding rules."""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data import modis
from repro.data.pipeline import Prefetcher, anyres_select, filter_empty_tiles
from repro.data.synthetic import TokenDataset, TokenDatasetConfig
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine
from repro.serve import ServeEngine
from repro.models import init_params
from repro.sharding.logical import make_rules, spec_for


# ---------------------------------------------------------------------- optim

def test_adamw_first_step_is_signed_lr():
    """After one step with wd=0, |delta| ~= lr * sign(grad) (bias-corrected)."""
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.array([1.0, -2.0, 3.0, -4.0])}
    st = adamw_init(p)
    p2, st2 = adamw_update(p, g, st, lr=1e-2, weight_decay=0.0)
    delta = np.asarray(p2["w"] - p["w"])
    np.testing.assert_allclose(delta, -1e-2 * np.sign(np.asarray(g["w"])),
                               rtol=1e-4)
    assert int(st2.step) == 1


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - math.sqrt(90.0)) < 1e-4
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert abs(n2 - 1.0) < 1e-4


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 0.11
    assert lrs[99] < 0.2
    assert max(lrs) <= 1.0 + 1e-6


# ----------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3))}}
    for s in (1, 2, 3):
        ck.save(s, tree)
    assert ck.latest_step() == 3
    got = ck.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(5))
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2  # gc keeps 2


def test_checkpoint_ignores_incomplete(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(3)}
    ck.save(5, tree)
    # simulate crash: LATEST points at a dir whose manifest is gone
    os.remove(os.path.join(str(tmp_path), "step_00000005", "manifest.json"))
    assert ck.latest_step() is None


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with explicit shardings (elastic restart path)."""
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shd = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    got = ck.restore(1, tree, shardings=shd)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding.is_equivalent_to(shd["w"], 2)


# ----------------------------------------------------------------------- data

def test_token_dataset_deterministic_and_host_sharded():
    cfg = TokenDatasetConfig(vocab_size=64, seq_len=8, global_batch=8)
    ds = TokenDataset(cfg)
    a = ds.batch(3)
    b = ds.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    h0 = ds.batch(3, host_id=0, num_hosts=2)
    h1 = ds.batch(3, host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher():
    pf = Prefetcher(iter(range(5)), depth=2)
    assert list(pf) == [0, 1, 2, 3, 4]


def test_ychg_filter_and_anyres():
    tiles = np.stack([
        np.zeros((32, 32), np.uint8),
        modis.striped(32, 9),
        modis.snowfield(32, seed=1),
    ])
    kept = filter_empty_tiles(tiles)
    assert kept.shape[0] == 2
    img = modis.snowfield(128, seed=2)
    offs = anyres_select(img, tile=32, k=3)
    assert len(offs) == 3 and all(len(o) == 2 for o in offs)


# ---------------------------------------------------------------------- serve

def test_serve_engine_greedy_matches_forward():
    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=61, param_dtype="float32",
        activation_dtype="float32", remat="none", attn_chunk=64,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=32)
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    out = eng.generate(prompts, max_new=6)
    assert out.tokens.shape == (2, 6)
    # greedy decode must be deterministic
    out2 = eng.generate(prompts, max_new=6)
    np.testing.assert_array_equal(out.tokens, out2.tokens)


# ------------------------------------------------------------------- sharding

def test_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules("train")
    # 14 heads on a 16-way model axis must fall back to replication —
    # emulate with a mesh where the axis size doesn't divide.
    mesh16 = jax.make_mesh((1,), ("model",)) if False else mesh
    spec = spec_for(("embed", "heads", None), rules, mesh, (8, 14, 64))
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_spec_skips_missing_mesh_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules("train")
    spec = spec_for(("act_batch", "act_seq"), rules, mesh, (8, 16))
    # ("pod","data") rule with no pod axis -> data only
    assert spec == jax.sharding.PartitionSpec("data")


def test_spec_no_duplicate_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"a": "model", "b": "model"}
    spec = spec_for(("a", "b"), rules, mesh, (4, 4))
    assert spec == jax.sharding.PartitionSpec("model")
