"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on CPU with the full production loop (checkpointing, resume,
metrics). Reduced-width qwen2 config — same code path the pod-scale configs
lower in the dry-run.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params is slow on 1 CPU core; --tiny trains a 2M model instead.)
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import TokenDataset, TokenDatasetConfig
from repro.models import count_params, init_params
from repro.optim import adamw_init
from repro.train import TrainLoop, TrainLoopConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("qwen2-0.5b").scaled(
            name="qwen2-2m", num_layers=2, d_model=128, num_heads=4,
            num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
            param_dtype="float32", activation_dtype="float32",
            remat="none", attn_chunk=256,
        )
        batch, seq = 8, 256
    else:
        cfg = get_config("qwen2-0.5b").scaled(
            name="qwen2-100m", num_layers=8, d_model=512, num_heads=8,
            num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32_768,
            param_dtype="float32", activation_dtype="float32",
            remat="none", attn_chunk=512,
        )
        batch, seq = 8, 512

    print(f"model {cfg.name}: {count_params(cfg) / 1e6:.1f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    ds = TokenDataset(TokenDatasetConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        n_patterns=256,
    ))
    step = jax.jit(make_train_step(
        cfg, peak_lr=1e-3, warmup_steps=20, total_steps=args.steps,
    ))

    loop = TrainLoop(step, TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=100,
        log_every=10,
    ))
    params, opt, start = loop.resume_or_init(params, opt)
    if start:
        print(f"resumed from step {start}")

    def batches():
        i = start
        while True:
            yield {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
            i += 1

    loop.run(params, opt, batches(), start_step=start)
    print(f"done; nan_skips={loop.nan_skips} deadline_misses={loop.deadline_misses}")


if __name__ == "__main__":
    main()
