"""The yCHG ROI service behind its HTTP front end, end to end.

Starts the asyncio front end on a loopback ephemeral port (ServerThread:
the server runs on its own event-loop thread, so this script stays plain
blocking Python), then drives it like a remote client would:

  1. one mask            -> POST /v1/analyze, result bit-identical to
                            in-process ``service.submit``;
  2. a streamed batch    -> POST /v1/analyze_batch, NDJSON lines arriving
                            in the server's completion order;
  3. overload            -> HTTP 429 + Retry-After once the per-bucket
                            admission allowance is full;
  4. observability       -> /healthz and /metrics (Prometheus text).

Run:  PYTHONPATH=src python examples/roi_service_http.py
"""

import numpy as np

from repro.frontend import FrontendOverloaded, ServerThread, YCHGClient
from repro.service import ServiceConfig, YCHGService


def main():
    rng = np.random.default_rng(0)
    masks = [(rng.random((96, 128)) < 0.45).astype(np.uint8)
             for _ in range(6)]

    config = ServiceConfig(bucket_sides=(128,), max_batch=4,
                           max_delay_ms=2.0, bucket_queue_depth=64)
    with YCHGService(config=config) as service, \
            ServerThread(service) as server, \
            YCHGClient("127.0.0.1", server.port) as client:
        print(f"front end on http://127.0.0.1:{server.port}  "
              f"({client.health()['backend']} backend)")

        # 1. single mask over the wire == in-process submit, bit for bit
        wire = client.analyze(masks[0])
        local = service.submit(masks[0]).result(timeout=60).to_host()
        assert all(np.array_equal(wire[k], np.asarray(v))
                   for k, v in local.items())
        print(f"single mask: {int(wire['n_hyperedges'])} hyperedges "
              f"(bit-identical to in-process)")

        # 2. streamed batch: results arrive in completion order
        print("streamed batch:")
        for item in client.analyze_batch(masks, ids=[f"roi-{i}" for i in
                                                     range(len(masks))]):
            print(f"  {item.id}: {int(item.result['n_hyperedges'])} "
                  f"hyperedges")

        # 4. observability
        for line in client.metrics_text().splitlines():
            if line.startswith(("ychg_submitted", "ychg_batches",
                                "ychg_cache_hits", "ychg_p95")):
                print(f"  /metrics  {line}")

    # 3. overload: one admission slot, held by a parked request -> the
    # wire answer is 429 with a drain-rate-derived Retry-After
    tight = ServiceConfig(bucket_sides=(128,), max_batch=4,
                          max_delay_ms=10_000.0, max_queue_depth=1,
                          overload_policy="shed")
    with YCHGService(config=tight) as service:
        holder = service.submit(masks[0])
        with ServerThread(service) as server, \
                YCHGClient("127.0.0.1", server.port) as client:
            try:
                client.analyze(masks[1])
            except FrontendOverloaded as e:
                print(f"overload: HTTP 429, retry after "
                      f"{e.retry_after_s:.2f}s")
    holder.result(timeout=60)   # admitted work still completed on close


if __name__ == "__main__":
    main()
