"""Quickstart: the paper's two-step yCHG algorithm on a synthetic scene.

The canonical entry point is ``repro.engine.Engine``: one engine, every
backend, device-resident results. ``backend="auto"`` resolves from the
registry (jit'd jnp on CPU/GPU, the fused single-launch Pallas kernel on
TPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import regions
from repro.data import modis
from repro.engine import Engine, YCHGConfig
from repro.service import ServiceConfig, YCHGService


def main():
    # A MODIS-like snow-cover mask (the paper's dataset, synthesised offline)
    img = modis.snowfield(512, seed=7)
    print(f"scene: {img.shape}, coverage {img.mean():.1%}")

    # Step 1 + 2 on the "GPU": one engine call, result stays on device
    engine = Engine()  # backend="auto"
    result = engine.analyze(img)
    print(f"engine dispatched to backend={engine.resolve_backend()!r}")
    out = result.to_host()  # host copy only where the example prints
    print(f"step 1: cut-vertex counts per column — max runs "
          f"{out['runs'].max()}, mean {out['runs'].mean():.1f}")
    print(f"step 2: {out['n_transitions']} transition columns, "
          f"{out['n_hyperedges']} yConvex hyperedges")

    # Paper's serial baseline agrees exactly (same engine API, host backend)
    ser = Engine(YCHGConfig(backend="serial")).analyze(img).to_host()
    assert np.array_equal(out["runs"], ser["runs"])
    print("serial baseline agrees exactly")

    # Beyond the poster: materialise the decomposition
    edges = regions.decompose(img)
    biggest = max(edges, key=lambda e: e.area)
    print(f"materialised {len(edges)} y-convex pieces; largest spans "
          f"cols {biggest.col_span} area {biggest.area}px "
          f"(total area {regions.total_area(img)}px)")

    # Serving: the same computation behind the production front end.
    # YCHGService micro-batches single-mask requests into shape-bucketed
    # stacks on a shared engine and caches results by content — a repeated
    # mask is served from the cache without touching any backend. Flushes
    # pad to the power-of-two sub-batch covering their occupancy (a lone
    # request dispatches a (1, 512, 512) stack, not (4, 512, 512)), and
    # max_queue_depth bounds admitted work: past it, submit blocks
    # (overload_policy="block", backpressure) or raises ServiceOverloaded
    # ("shed") — shed/blocked counts land in ServiceMetrics.
    cfg = ServiceConfig(bucket_sides=(512,), max_batch=4,
                        max_queue_depth=64, overload_policy="block")
    with YCHGService(config=cfg) as svc:
        fresh = svc.analyze(img)            # computed (same result as above)
        repeat = svc.analyze(img.copy())    # same bytes -> cache hit
        assert repeat is fresh              # the cached object itself
        assert np.array_equal(np.asarray(fresh.n_hyperedges),
                              [out["n_hyperedges"]])
        m = svc.metrics()
        print(f"service: {m.completed} served "
              f"({m.completed_from_cache} from cache) on "
              f"backend={m.backend!r}, hit rate {m.hit_rate:.0%}, "
              f"p95 {m.p95_latency_ms:.1f}ms, "
              f"dispatched shapes {m.compiled_shapes}, "
              f"shed {m.shed} / blocked {m.blocked}")


if __name__ == "__main__":
    main()
