"""Quickstart: the paper's two-step yCHG algorithm on a synthetic scene.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import regions, ychg
from repro.core.api import analyze_image
from repro.data import modis


def main():
    # A MODIS-like snow-cover mask (the paper's dataset, synthesised offline)
    img = modis.snowfield(512, seed=7)
    print(f"scene: {img.shape}, coverage {img.mean():.1%}")

    # Step 1 + 2 on the "GPU" (data-parallel JAX; Pallas kernel on TPU)
    out = analyze_image(img, backend="jax")
    print(f"step 1: cut-vertex counts per column — max runs "
          f"{out['runs'].max()}, mean {out['runs'].mean():.1f}")
    print(f"step 2: {out['n_transitions']} transition columns, "
          f"{out['n_hyperedges']} yConvex hyperedges")

    # Paper's serial baseline agrees exactly
    ser = analyze_image(img, backend="serial")
    assert np.array_equal(out["runs"], ser["runs"])
    print("serial baseline agrees exactly")

    # Beyond the poster: materialise the decomposition
    edges = regions.decompose(img)
    biggest = max(edges, key=lambda e: e.area)
    print(f"materialised {len(edges)} y-convex pieces; largest spans "
          f"cols {biggest.col_span} area {biggest.area}px "
          f"(total area {regions.total_area(img)}px)")


if __name__ == "__main__":
    main()
