"""A compound (multi-op pipeline) request through the ROI service.

The multi-op platform serves more than yCHG: every operator registers
under ``(op, platform)`` in the backend registry, requests pick one with
``submit(mask, op=...)`` / ``POST /v1/{op}``, and an ordered op chain can
run as ONE device-resident compound request (``submit_pipeline`` /
``POST /v1/pipeline``) — no host round trip between stages, bit-identical
to issuing the stages as separate requests.

This example denoises a speckled float image (P-HGRMS-style hypergraph
RMS filter, op ``denoise``) and feeds the filtered image straight into
the yCHG ROI analysis (op ``ychg``), three ways:

  1. in-process single ops — ``submit(..., op="denoise")``, then
     ``submit`` of the result (two device round trips);
  2. in-process compound   — ``service.pipeline(img, ["denoise",
     "ychg"])`` (one submit, stages chained on device);
  3. over the wire         — ``client.pipeline`` against the HTTP front
     end's ``POST /v1/pipeline``.

All three agree bit for bit, which the script asserts.

Run:  PYTHONPATH=src python examples/roi_pipeline.py
"""

import numpy as np

from repro.frontend import ServerThread, YCHGClient
from repro.service import Service, ServiceConfig


def main():
    rng = np.random.default_rng(0)
    # a smooth field with salt-and-pepper speckle: the denoise stage's
    # outlier test (|x - mean| > tau * rms) replaces the spikes
    yy, xx = np.mgrid[0:96, 0:128]
    img = np.maximum(
        0.0, 0.55 * np.sin(yy / 9.0) * np.cos(xx / 13.0) - 0.05
    ).astype(np.float32)   # zero background between the lobes
    spikes = rng.random(img.shape) < 0.02
    img[spikes] = rng.random(spikes.sum()).astype(np.float32) * 4.0

    config = ServiceConfig(bucket_sides=(128,), max_batch=4,
                           max_delay_ms=1.0)
    with Service(config=config) as service, \
            ServerThread(service) as server, \
            YCHGClient("127.0.0.1", server.port) as client:
        # 1. the stages as separate requests (host hop between them)
        filtered = service.submit(img, op="denoise").result(timeout=60)
        stage2 = service.submit(
            np.asarray(filtered.to_host()["image"]),
            op="ychg").result(timeout=60).to_host()

        # 2. one compound request: denoise -> ychg chained on device
        compound = service.pipeline(img, ["denoise", "ychg"],
                                    timeout=60).to_host()
        for field, want in stage2.items():
            assert np.array_equal(np.asarray(compound[field]),
                                  np.asarray(want)), field
        print("compound denoise+ychg == the stages as separate submits "
              f"({int(np.asarray(compound['n_hyperedges']))} hyperedges "
              "in the filtered image)")

        # 3. the same compound request over the HTTP front end
        wire = client.pipeline(img, ["denoise", "ychg"])
        for field, want in compound.items():
            assert np.array_equal(wire[field], np.asarray(want)), field
        print("POST /v1/pipeline bit-identical to the in-process compound "
              "request")

        # the per-stage spans/histograms the compound request leaves
        # behind (docs/observability.md): one pipeline.<op> series per
        # stage, keyed by the compound bucket
        for line in client.metrics_text().splitlines():
            if line.startswith("ychg_stage_seconds_count") \
                    and "pipeline." in line:
                print(f"  /metrics  {line}")


if __name__ == "__main__":
    main()
