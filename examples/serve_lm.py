"""Batched serving example: prefill + decode with the fixed-slot engine.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import count_params, init_params
from repro.serve import ServeEngine


def main():
    cfg = get_config("qwen2-0.5b").scaled(
        name="qwen2-serve-tiny", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=4096,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=256,
    )
    print(f"serving {cfg.name}: {count_params(cfg) / 1e6:.1f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=192)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (8, 64)).astype(np.int32)

    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=64, temperature=0.8, seed=1)
    dt = time.perf_counter() - t0
    total = out.tokens.size
    print(f"batch=8 prompt=64 generated {out.n_generated} steps "
          f"({total} tokens) in {dt:.2f}s -> {total / dt:.1f} tok/s (1-core CPU)")
    print("sample:", out.tokens[0, :16].tolist())

    # greedy determinism check
    a = eng.generate(prompts[:2], max_new=8)
    b = eng.generate(prompts[:2], max_new=8)
    assert np.array_equal(a.tokens, b.tokens)
    print("greedy decode deterministic — OK")


if __name__ == "__main__":
    main()
