"""Granule-scale scene analysis with a resumable bulk job, end to end.

A *granule* is one scene too large to want in a single device call — here
synthetic MODIS-like snow masks, windowed into full-width tile rows and
streamed through the engine with exact seam stitching (the stitched result
is bit-identical to analysing the unsplit scene). The walkthrough:

  1. stitch parity       -> SceneRunner over 8-row strips equals one
                            whole-scene engine.analyze call, bit for bit;
  2. a bulk job          -> a 3-granule manifest run to completion, one
                            deterministic .ychg result file per granule;
  3. kill + resume       -> the same manifest interrupted mid-granule,
                            resumed from its checkpoint, and the output
                            bytes compared equal to the uninterrupted
                            run's — the resume contract;
  4. progress            -> the SceneProgress counters a service would
                            surface on /metrics.

Run:  PYTHONPATH=src python examples/roi_scene_bulk.py
"""

import os
import tempfile

import numpy as np

from repro.data import scenes
from repro.engine import Engine
from repro.scene import (
    BulkJob,
    BulkJobConfig,
    GranuleReader,
    SceneProgress,
    SceneRunner,
    read_scene_result,
    synthetic_manifest,
)


def main():
    engine = Engine()

    # 1. stitch parity: strips + seam correction == whole scene, exactly.
    #    45 rows over 8-row strips leaves a ragged, zero-padded last strip.
    mask = scenes.scene(45, 64, seed=7, cell=8)
    reader = GranuleReader.from_array(mask, tile_h=8, granule_id="demo")
    stitched = SceneRunner(engine, stack_tiles=2).analyze_scene(reader)
    whole = engine.analyze(mask).to_host()
    assert all(np.array_equal(np.asarray(whole[f]),
                              np.asarray(getattr(stitched, f)))
               for f in whole)
    print(f"stitch parity: {reader.n_tiles} strips of a 45x64 scene -> "
          f"{int(stitched.n_hyperedges)} hyperedges, bit-identical to the "
          f"whole-scene call")

    manifest = synthetic_manifest(3, height=96, width=64, seed=100, cell=8)
    with tempfile.TemporaryDirectory() as tmp:
        def config(tag):
            return BulkJobConfig(out_dir=os.path.join(tmp, tag, "out"),
                                 ckpt_dir=os.path.join(tmp, tag, "ckpt"),
                                 tile_h=16, stack_tiles=2,
                                 checkpoint_every=2)

        # 2. run the manifest to completion: one result file per granule
        job = BulkJob(engine, manifest, config("straight"))
        report = job.run()
        print(f"bulk job: {report.granules_done} granules, "
              f"{report.tiles_done} tiles in {report.elapsed_s:.2f}s")
        for spec in manifest:
            res = read_scene_result(job.output_path(spec))
            print(f"  {spec.granule_id}: {int(res.n_hyperedges)} "
                  f"hyperedges over {res.height}x{res.width}")

        # 3. the resume contract: interrupt mid-granule (max_stacks plays
        #    the part of SIGTERM — `serve.py scene` wires the real one),
        #    restart with the same directories, compare output bytes
        progress = SceneProgress()
        first = BulkJob(engine, manifest, config("killed"),
                        progress=progress).run(max_stacks=3)
        print(f"interrupted after {first.stacks_done} stacks "
              f"({first.status})")
        second = BulkJob(engine, manifest, config("killed"),
                         progress=progress).run()
        assert second.completed and second.resumes == 1
        for spec in manifest:
            a = os.path.join(tmp, "straight", "out",
                             f"{spec.granule_id}.ychg")
            b = os.path.join(tmp, "killed", "out",
                             f"{spec.granule_id}.ychg")
            with open(a, "rb") as fa, open(b, "rb") as fb:
                assert fa.read() == fb.read()
        print("resumed run's outputs are byte-identical to the "
              "uninterrupted run's")

        # 4. progress counters (a service attaches these to /metrics via
        #    service.attach_scene_progress(progress))
        snap = progress.snapshot()
        print(f"progress: tiles {snap.tiles_done}/{snap.tiles_total}, "
              f"granules {snap.granules_done}/{snap.granules_total}, "
              f"resumes {snap.resumes}, "
              f"stitch {snap.stitch_time_s * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
