"""End-to-end satellite ROI pipeline (the paper's deployment scenario).

Tiles of a large MODIS-like scene flow through the data pipeline behind a
single ``Engine`` built from the workload config:
  1. background prefetch of tile batches,
  2. the paper's two-step yCHG operator on device — the engine's fused
     backend: one kernel launch per tile batch (vs two launches per image
     for the original step-1/step-2 pipeline),
  3. empty-tile filtering + anyres crop ranking for a VLM frontend,
  4. the same engine with a mesh attached: the batch shard_maps over the
     device mesh (a 1-device CPU mesh degrades to the plain fused call;
     ragged batches are padded and stripped inside the engine).

Run:  PYTHONPATH=src python examples/satellite_roi.py
"""

import time

import numpy as np

from repro.configs.ychg_modis import config as workload_config
from repro.data import modis
from repro.data.pipeline import Prefetcher, anyres_select, filter_empty_tiles, ychg_stats
from repro.engine import Engine
from repro.sharding import make_batch_mesh


def tile_stream(scene: np.ndarray, tile: int):
    h, w = scene.shape
    batch = []
    for y in range(0, h - tile + 1, tile):
        for x in range(0, w - tile + 1, tile):
            batch.append(scene[y:y + tile, x:x + tile])
            if len(batch) == 8:
                yield np.stack(batch)
                batch = []
    if batch:
        yield np.stack(batch)


def main():
    scene = modis.snowfield(1024, seed=11)
    print(f"scene {scene.shape}, coverage {scene.mean():.1%}")

    wl = workload_config()
    # force the fused single-launch path (auto would pick jit'd jnp on CPU)
    engine = Engine(wl.engine.to_engine_config(backend="fused"))

    t0 = time.perf_counter()
    n_tiles = n_kept = n_edges = n_launches = 0
    for batch in Prefetcher(tile_stream(scene, 128), depth=2):
        stats = ychg_stats(batch, engine=engine)  # ONE kernel launch/batch
        # filter on the stats already in hand — no second launch per batch
        kept = filter_empty_tiles(batch, stats=stats)
        n_tiles += len(batch)
        n_kept += len(kept)
        n_edges += int(stats["n_hyperedges"].sum())
        n_launches += 1
    dt = time.perf_counter() - t0
    print(f"processed {n_tiles} tiles in {dt:.2f}s "
          f"({n_tiles / dt:.1f} tiles/s 1-core CPU); kept {n_kept}, "
          f"total hyperedges {n_edges}")
    print(f"fused kernel launches: {n_launches} "
          f"(two-pass pipeline would have issued {2 * n_tiles})")

    # the same engine as a streaming operator: device-resident results per
    # batch, host copy only for the running total
    streamed = sum(
        int(np.asarray(r.n_hyperedges).sum())
        for r in engine.analyze_stream(tile_stream(scene, 128))
    )
    assert streamed == n_edges
    print(f"analyze_stream pass agrees: {streamed} hyperedges")

    # batch-sharded pass over the full tile stack (multi-device MODIS path):
    # the fused backend with a mesh attached — nothing else changes
    mesh = make_batch_mesh()
    meshed = engine.with_mesh(mesh)
    stack = np.stack([t for b in tile_stream(scene, 128) for t in b])
    sharded = meshed.analyze_batch(stack)
    assert sharded.batch_size == stack.shape[0]  # pad stripped internally
    assert int(np.asarray(sharded.n_hyperedges).sum()) == n_edges
    print(f"batch-sharded pass over {stack.shape[0]} tiles on a "
          f"{dict(mesh.shape)} mesh: total hyperedges "
          f"{int(np.asarray(sharded.n_hyperedges).sum())} (matches streaming pass)")

    # anyres: pick the 5 most structurally complex crops for the VLM frontend
    offs = anyres_select(scene, tile=256, k=5)
    print(f"anyres-selected crops (by yCHG hyperedge density): {offs}")


if __name__ == "__main__":
    main()
