"""End-to-end satellite ROI pipeline (the paper's deployment scenario).

Tiles of a large MODIS-like scene flow through the data pipeline:
  1. background prefetch of tile batches,
  2. the paper's two-step yCHG operator on device (batched),
  3. empty-tile filtering + anyres crop ranking for a VLM frontend.

Run:  PYTHONPATH=src python examples/satellite_roi.py
"""

import time

import numpy as np

from repro.data import modis
from repro.data.pipeline import Prefetcher, anyres_select, filter_empty_tiles, ychg_stats


def tile_stream(scene: np.ndarray, tile: int):
    h, w = scene.shape
    batch = []
    for y in range(0, h - tile + 1, tile):
        for x in range(0, w - tile + 1, tile):
            batch.append(scene[y:y + tile, x:x + tile])
            if len(batch) == 8:
                yield np.stack(batch)
                batch = []
    if batch:
        yield np.stack(batch)


def main():
    scene = modis.snowfield(1024, seed=11)
    print(f"scene {scene.shape}, coverage {scene.mean():.1%}")

    t0 = time.perf_counter()
    n_tiles = n_kept = n_edges = 0
    for batch in Prefetcher(tile_stream(scene, 128), depth=2):
        stats = ychg_stats(batch)
        kept = filter_empty_tiles(batch)
        n_tiles += len(batch)
        n_kept += len(kept)
        n_edges += int(stats["n_hyperedges"].sum())
    dt = time.perf_counter() - t0
    print(f"processed {n_tiles} tiles in {dt:.2f}s "
          f"({n_tiles / dt:.1f} tiles/s 1-core CPU); kept {n_kept}, "
          f"total hyperedges {n_edges}")

    # anyres: pick the 5 most structurally complex crops for the VLM frontend
    offs = anyres_select(scene, tile=256, k=5)
    print(f"anyres-selected crops (by yCHG hyperedge density): {offs}")


if __name__ == "__main__":
    main()
